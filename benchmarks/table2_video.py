"""Table 2 analogue: text-to-video on the reduced HunyuanVideo-like model
(3D tokens: 4 frames × 16 tokens). VBench-proxy = conditioning score +
temporal consistency. Also runs the serving engine per-request to report
the sample-adaptive allocation split (paper §1: 57.5% of samples at 6.48×,
42.5% at 5.82×)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common as C

METHODS = [
    "full",
    "steps_0.22",
    "fora_5",
    "taylorseer_5_1",
    "teacache_2.7",
    "speca_0.3", "speca_0.6",
]


def run(batch: int = 8, methods=None, seed: int = 5,
        n_requests: int = 12):
    cfg, dcfg, params = C.get_model("video")
    cond = C.make_cond(cfg, dcfg, batch)
    key = jax.random.PRNGKey(seed)
    templates = C.class_templates(cfg, dcfg)

    rows = []
    x_full = None
    for name in (methods or METHODS):
        res = C.run_method(name, cfg, dcfg, params, cond, batch, key)
        if name == "full":
            x_full = res.samples
        rows.append(C.evaluate(res, x_full, cfg, dcfg, cond, templates,
                               None))
    C.print_table("table2_video (t2v, RF 50 steps, 4 frames)", rows)
    C.write_result("table2_video", rows)

    # --- sample-adaptive allocation via the serving engine --------------
    from repro.configs import SpeCaConfig
    from repro.core.complexity import forward_flops
    from repro.serving import Request, SpeCaEngine, allocation_report
    import jax.numpy as jnp

    scfg = SpeCaConfig(taylor_order=2, max_draft=8, tau0=0.4, beta=0.9)
    engine = SpeCaEngine(cfg, params, dcfg, scfg)
    reqs = []
    for i in range(n_requests):
        c = C.make_cond(cfg, dcfg, 1, seed=1000 + i)
        reqs.append(Request(request_id=i, cond=c, seed=i))
    results = engine.serve(reqs)
    n_tok = (dcfg.latent_size // cfg.patch_size) ** 2 * dcfg.num_frames
    report = allocation_report(results, forward_flops(cfg, n_tok))
    report = {k: round(v, 4) for k, v in report.items()}
    print("\n== sample-adaptive allocation (serving engine) ==")
    print(report)
    C.write_result("table2_allocation", [report])
    return rows, report


if __name__ == "__main__":
    run()
