"""§Perf before/after: baseline vs optimized dry-run artifacts.

Compares ``artifacts/dryrun_baseline/*_cal.json`` (pre-optimization,
paper-faithful sharding) against ``artifacts/dryrun/*_cal.json`` (after
the EXPERIMENTS.md §Perf iterations) for every (arch × shape).
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _load(dirname):
    out = {}
    for p in glob.glob(os.path.join(ART, dirname, "*_cal.json")):
        r = json.load(open(p))
        out[(r["arch"].replace("+swa", ""), r["shape"])] = r
    return out


def run():
    base = _load("dryrun_baseline")
    opt = _load("dryrun")
    rows = []
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]

        def term(r):
            return max(r["flops_per_device_corrected"] / PEAK_FLOPS,
                       r["bytes_per_device_corrected"] / HBM_BW,
                       r["collective_wire_bytes_corrected"] / ICI_BW)

        tb, to = term(b), term(o)
        rows.append({
            "arch": key[0], "shape": key[1],
            "bound_s_before": f"{tb:.3e}",
            "bound_s_after": f"{to:.3e}",
            "speedup": round(tb / max(to, 1e-12), 2),
            "flops_ratio": round(
                b["flops_per_device_corrected"]
                / max(o["flops_per_device_corrected"], 1), 2),
            "bytes_ratio": round(
                b["bytes_per_device_corrected"]
                / max(o["bytes_per_device_corrected"], 1), 2),
            "wire_ratio": round(min(
                b["collective_wire_bytes_corrected"]
                / max(o["collective_wire_bytes_corrected"], 1), 999.0), 2),
            "kind": b["kind"],
        })
    from benchmarks import common as C
    C.print_table("perf before/after (dominant roofline term, per step)",
                  rows)
    C.write_result("perf_before_after", rows)
    if rows:
        import statistics
        for kind in ("train", "prefill", "decode"):
            sp = [r["speedup"] for r in rows if r["kind"] == kind]
            if sp:
                print(f"{kind:8s}: median {statistics.median(sp):.2f}× "
                      f" max {max(sp):.2f}×  min {min(sp):.2f}×")
    return rows


if __name__ == "__main__":
    run()
