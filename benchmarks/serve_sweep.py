"""Arrival-rate sweep: find each scheduler's saturation knee.

The ROADMAP's open load-harness item: drive open-loop Poisson arrivals
at increasing rate λ (requests per scheduler tick) through each
admission scheduler (FIFO / SJF / EDF / WFQ) and locate the *saturation
knee* — the first λ whose p50 completion latency exceeds
``--knee-factor ×`` the latency at the lowest (uncongested) λ. Below
the knee the engine absorbs arrivals (latency ≈ service time); above
it the queue grows for the length of the run and latency is dominated
by waiting. The knee is the scheduler's usable-capacity summary, and
charting it across PRs (``tools/plot_perf_trajectory.py``) is the
regression alarm for serving capacity.

Method per (scheduler, λ) point:

  * the SAME seeded arrival trace replays against every scheduler
    (mixed τ0 / schedule length / tenant / deadline — the policy mix
    that differentiates SJF/EDF/WFQ from FIFO);
  * one engine PER SCHEDULER serves every λ point in sequence —
    compiled lane programs survive ``shutdown()``, so only the first
    point pays compile;
  * latency is measured in scheduler ticks (completion − arrival) from
    the drive loop, queue depth from the observability registry's
    per-tick ``speca_queue_depth``/``speca_in_flight`` series
    (``repro.obs``), sliced per point.

The run also measures **observability overhead**: interleaved obs-on /
obs-off drives of the same fixed-λ workload (best-of ``--overhead-
repeats`` each). ``--gate`` asserts the acceptance criteria — a knee
found for all four schedulers AND obs-on within ``--overhead-bound``
(default 3%) of obs-off — exiting nonzero otherwise (the CI leg runs
with ``--gate``).

Artifacts: ``serve_sweep.json`` (per-point rows),
``serve_sweep_knee.json`` (per-scheduler knee rows),
``serve_sweep_overhead.json`` (the obs on/off comparison).

Run (repo root on the path for ``benchmarks.common``):
  PYTHONPATH=src:. python benchmarks/serve_sweep.py \
      --requests 24 --lanes 4 --steps 6
  PYTHONPATH=src:. python benchmarks/serve_sweep.py --gate
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_model, print_table, write_result
from repro.configs import SpeCaConfig
from repro.serving import Request, RequestPolicy, SpeCaEngine

SCHEDULERS = ("fifo", "sjf", "edf", "wfq")
TENANTS = (("gold", 4.0), ("silver", 1.0), ("bronze", 1.0))


def build_arrivals(lam: float, cfg, args):
    """Seeded Poisson(λ)/tick arrival trace: ``[(tick, Request), ...]``.

    The policy mix (τ0, schedule length, tenant/weight, deadlines)
    matches serve_load's heterogeneous traffic so SJF/EDF/WFQ have
    something to reorder; the same seed at every λ and scheduler keeps
    points comparable."""
    rng = np.random.default_rng(args.seed)
    trace, t, i = [], 0, 0
    while i < args.requests:
        for _ in range(min(int(rng.poisson(lam)), args.requests - i)):
            tenant, weight = TENANTS[int(rng.integers(len(TENANTS)))]
            tau0 = float(rng.choice([0.2, 0.4, 0.8]))
            max_steps = int(max(args.steps // 2, 1)) \
                if rng.random() < 0.3 else None
            deadline = float(t + args.steps * (3 + 2 * rng.random())) \
                if rng.random() < 0.3 else None
            trace.append((t, Request(
                request_id=i,
                cond={"labels": jnp.asarray([i % cfg.num_classes])},
                seed=i,
                policy=RequestPolicy(tau0=tau0, max_steps=max_steps,
                                     deadline=deadline, tenant=tenant,
                                     weight=weight))))
            i += 1
        t += 1
    return trace


def drive_point(engine: SpeCaEngine, trace, *, max_ticks: int):
    """Replay one arrival trace to completion. Returns (per-request
    latency ticks, loop ticks, wall seconds, peak outstanding work) —
    the peak read from the obs series slice for this point when the
    engine has obs, else tracked host-side (the obs-off overhead leg)."""
    backlog = list(trace)
    arrivals = {}
    lats = []
    obs = engine.obs is not None
    n0 = len(engine.obs.metrics.series("speca_queue_depth")) if obs else 0
    peak_off = 0
    t0 = time.time()
    t = 0
    while backlog or engine.pending() or engine.in_flight():
        if t >= max_ticks:
            raise RuntimeError(f"sweep point did not drain in "
                               f"{max_ticks} ticks")
        while backlog and backlog[0][0] <= t:
            tick_, req = backlog.pop(0)
            arrivals[engine.submit(req).ticket_id] = tick_
        if not obs:
            peak_off = max(peak_off,
                           engine.pending() + engine.in_flight())
        for res in engine.tick():
            lats.append(t + 1 - arrivals.pop(res.ticket_id))
            engine.release(res.ticket_id)
        t += 1
    wall = time.time() - t0
    if obs:
        qd = engine.obs.metrics.series("speca_queue_depth").points()[n0:]
        fl = engine.obs.metrics.series("speca_in_flight").points()[n0:]
        peak = max((q + f for (_, q), (_, f) in zip(qd, fl)), default=0)
    else:
        peak = peak_off
    engine.shutdown()     # discard sessions; compiled programs survive
    return lats, t, wall, int(peak)


def make_engine(cfg, params, dcfg, scfg, args, *, scheduler: str,
                obs: bool = True) -> SpeCaEngine:
    eng = SpeCaEngine(cfg, params, dcfg, scfg, scheduler=scheduler,
                      lanes=args.lanes, obs=obs)
    eng.warmup({"labels": jnp.asarray([0])}, lanes=args.lanes, mixed=True)
    return eng


def sweep_scheduler(eng: SpeCaEngine, sched: str, lams, cfg, args):
    """All λ points for one scheduler → (point rows, knee row)."""
    rows, base_p50, knee = [], None, None
    for lam in lams:
        trace = build_arrivals(lam, cfg, args)
        lats, ticks, wall, peak = drive_point(
            eng, trace, max_ticks=args.max_ticks)
        p50 = float(np.percentile(lats, 50))
        p99 = float(np.percentile(lats, 99))
        if base_p50 is None:
            base_p50 = p50
        rows.append({"scheduler": sched, "lam": round(lam, 4),
                     "requests": len(trace), "ticks": ticks,
                     "wall_s": round(wall, 2),
                     "req_per_s": round(len(trace) / max(wall, 1e-9), 3),
                     "p50_latency": round(p50, 1),
                     "p99_latency": round(p99, 1),
                     "qdepth_peak": peak,
                     "saturated": bool(p50 > args.knee_factor * base_p50)})
        if knee is None and p50 > args.knee_factor * base_p50:
            knee = {"scheduler": sched, "knee_lam": round(lam, 4),
                    "base_p50": round(base_p50, 1),
                    "knee_p50": round(p50, 1),
                    "knee_factor": args.knee_factor}
    if knee is None:
        knee = {"scheduler": sched, "knee_lam": None,
                "base_p50": round(base_p50, 1), "knee_p50": None,
                "knee_factor": args.knee_factor}
    return rows, knee


def measure_overhead(cfg, params, dcfg, scfg, args, lam: float):
    """Best-of-N interleaved obs-on / obs-off drives of the same
    fixed-λ workload. Interleaving (off, on, off, on, ...) and taking
    each side's best wall time squeezes out the machine-load noise a
    single pair would alias into the ratio."""
    eng_off = make_engine(cfg, params, dcfg, scfg, args,
                          scheduler="fifo", obs=False)
    eng_on = make_engine(cfg, params, dcfg, scfg, args,
                         scheduler="fifo", obs=True)
    trace = build_arrivals(lam, cfg, args)
    best_off = best_on = float("inf")
    for _ in range(args.overhead_repeats):
        _, _, w_off, _ = drive_point(eng_off, trace,
                                     max_ticks=args.max_ticks)
        _, _, w_on, _ = drive_point(eng_on, trace,
                                    max_ticks=args.max_ticks)
        best_off, best_on = min(best_off, w_off), min(best_on, w_on)
    return {"obs_off_s": round(best_off, 3), "obs_on_s": round(best_on, 3),
            "overhead_ratio": round(best_on / max(best_off, 1e-9), 4),
            "repeats": args.overhead_repeats,
            "lam": round(lam, 4), "requests": len(trace)}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dit", choices=["dit", "flux"])
    ap.add_argument("--requests", type=int, default=32,
                    help="requests per (scheduler, λ) point — enough "
                         "backlog that supercritical λ visibly queues")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=6,
                    help="diffusion schedule length")
    ap.add_argument("--scheduler", default=",".join(SCHEDULERS),
                    help="comma list of schedulers to sweep")
    ap.add_argument("--lam", default=None,
                    help="comma list of λ values; default is a "
                         "geometric grid around the lane-capacity "
                         "estimate lanes/steps")
    ap.add_argument("--knee-factor", type=float, default=2.0,
                    help="saturation threshold: first λ with p50 > "
                         "factor × base-λ p50")
    ap.add_argument("--overhead-repeats", type=int, default=3)
    ap.add_argument("--overhead-bound", type=float, default=1.03,
                    help="--gate fails when obs-on/obs-off exceeds this")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-ticks", type=int, default=100_000)
    ap.add_argument("--skip-overhead", action="store_true")
    ap.add_argument("--gate", action="store_true",
                    help="exit nonzero unless every scheduler has a "
                         "knee and obs overhead is within bound")
    args = ap.parse_args()

    cfg, dcfg, params = get_model(args.model)
    dcfg = dataclasses.replace(dcfg, num_inference_steps=args.steps)
    scfg = SpeCaConfig(taylor_order=2, max_draft=8, tau0=0.4, beta=0.9)

    # open-loop capacity estimate: `lanes` servers, ~`steps` ticks of
    # service per request → λ* ≈ lanes/steps requests per tick; the
    # grid brackets it so the final points are firmly supercritical
    cap = args.lanes / max(args.steps, 1)
    if args.lam:
        lams = [float(x) for x in args.lam.split(",") if x]
    else:
        lams = [round(cap * m, 4)
                for m in (0.25, 0.5, 1.0, 2.0, 4.0, 8.0)]
    scheds = [s.strip() for s in args.scheduler.split(",") if s.strip()]
    print(f"sweep: λ grid {lams} (capacity estimate {cap:.3f} req/tick), "
          f"schedulers {scheds}, {args.requests} requests/point")

    point_rows, knee_rows = [], []
    for sched in scheds:
        eng = make_engine(cfg, params, dcfg, scfg, args, scheduler=sched)
        rows, knee = sweep_scheduler(eng, sched, lams, cfg, args)
        point_rows += rows
        knee_rows.append(knee)
        print(f"{sched}: knee λ = {knee['knee_lam']} "
              f"(base p50 {knee['base_p50']} ticks → "
              f"{knee['knee_p50']} at the knee)")

    overhead = None
    if not args.skip_overhead:
        overhead = measure_overhead(cfg, params, dcfg, scfg, args,
                                    lam=cap)
        print(f"obs overhead: on {overhead['obs_on_s']}s vs off "
              f"{overhead['obs_off_s']}s → ratio "
              f"{overhead['overhead_ratio']} "
              f"(best of {overhead['repeats']})")

    print_table(f"serve_sweep ({args.model}, lanes={args.lanes}, "
                f"steps={args.steps})", point_rows)
    print_table("saturation knees", knee_rows)
    paths = [write_result("serve_sweep", point_rows),
             write_result("serve_sweep_knee", knee_rows)]
    if overhead is not None:
        paths.append(write_result("serve_sweep_overhead", [overhead]))
    print("wrote " + " and ".join(paths))

    if args.gate:
        missing = [k["scheduler"] for k in knee_rows
                   if k["knee_lam"] is None]
        if missing:
            print(f"GATE FAIL: no saturation knee found for {missing} "
                  f"(λ grid {lams} never saturated — widen it)")
            return 1
        if overhead is not None \
                and overhead["overhead_ratio"] > args.overhead_bound:
            print(f"GATE FAIL: obs overhead ratio "
                  f"{overhead['overhead_ratio']} exceeds "
                  f"{args.overhead_bound}")
            return 1
        print(f"GATE OK: knees for {[k['scheduler'] for k in knee_rows]}"
              + ("" if overhead is None else
                 f", obs overhead {overhead['overhead_ratio']} ≤ "
                 f"{args.overhead_bound}"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
