"""Serving throughput: sequential batch=1 vs per-lane batched scheduling.

Reports requests/s for both modes plus the Table-2-style sample-adaptive
allocation split (paper §1: 57.5% of samples at 6.48x / 42.5% at lower
acceleration): requests are bucketed at the median acceptance rate into
easy/hard and each bucket's realised FLOPs speedup is shown. Because the
lane scheduler reproduces the exact batch=1 accept trajectories, the two
modes serve identical work — the requests/s delta is pure scheduling.

``--devices 1,2,4`` adds one lane-scheduler row per device count D: the
engine lane-shards over a D-device ``('data',)`` mesh (requests/s per
device count is the CI artifact column tracking how serving capacity
scales with the mesh). The process must see max(D) devices — on CPU set
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` first.

Run (repo root must be on the path for ``benchmarks.common``):
  PYTHONPATH=src:. python benchmarks/serve_throughput.py \
      --requests 12 --lanes 4 --steps 30
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src:. python benchmarks/serve_throughput.py \
      --requests 8 --lanes 4 --steps 12 --devices 1,2,4
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp

from benchmarks.common import get_model, print_table, write_result
from repro.configs import SpeCaConfig
from repro.core.complexity import forward_flops
from repro.launch.mesh import make_lane_mesh
from repro.serving import Request, SpeCaEngine, allocation_report


def make_requests(cfg, n: int, *, offset: int = 0):
    return [Request(request_id=offset + i,
                    cond={"labels": jnp.asarray([i % cfg.num_classes])},
                    seed=offset + i)
            for i in range(n)]


def bench(engine: SpeCaEngine, requests, *, lanes: int):
    t0 = time.time()
    results = engine.serve(requests, lanes=lanes)
    wall = time.time() - t0
    return results, wall


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dit", choices=["dit", "flux"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--tau0", type=float, default=0.4)
    ap.add_argument("--accept-mode", default="per_sample",
                    choices=["per_sample", "batch"])
    ap.add_argument("--devices", default="1",
                    help="comma list of lane-shard device counts, e.g. "
                         "1,2,4 (needs that many visible devices)")
    args = ap.parse_args()
    device_counts = sorted({int(d) for d in args.devices.split(",")})

    cfg, dcfg, params = get_model(args.model)
    import dataclasses
    dcfg = dataclasses.replace(dcfg, num_inference_steps=args.steps)
    scfg = SpeCaConfig(taylor_order=2, max_draft=8, tau0=args.tau0,
                       beta=0.9)

    def make_engine(D: int) -> SpeCaEngine:
        return SpeCaEngine(cfg, params, dcfg, scfg,
                           accept_mode=args.accept_mode,
                           mesh=make_lane_mesh(D) if D > 1 else None)

    cond0 = {"labels": jnp.asarray([0])}
    reqs = make_requests(cfg, args.requests)
    engine = make_engine(1)
    # warm both paths so compile time stays out of the measurement
    engine.warmup(cond0, lanes=1)
    engine.warmup(cond0, lanes=min(args.lanes, args.requests))
    seq_results, seq_wall = bench(engine, reqs, lanes=1)

    # one lane-scheduler run per device count (D=1: plain engine; D>1:
    # the lane axis sharded over a D-device ('data',) mesh). The row is
    # labeled with the EFFECTIVE lane width — a mesh engine rounds the
    # width up to a multiple of D, so requesting --lanes 2 on D=4 serves
    # 4 lanes; hiding that would let a pure width gain masquerade as
    # device scaling in the per-device-count column.
    lane_runs = []
    for D in device_counts:
        eng = engine if D == 1 else make_engine(D)
        if D > 1:
            eng.warmup(cond0, lanes=min(args.lanes, args.requests))
        W_eff = eng.lane_width(args.lanes, len(reqs))
        results, wall = bench(eng, reqs, lanes=args.lanes)
        lane_runs.append((D, W_eff, results, wall))

    n_tok = (dcfg.latent_size // cfg.patch_size) ** 2 \
        * max(dcfg.num_frames, 1)
    fwd = forward_flops(cfg, n_tok)
    runs = [("batch=1", 1, 1, seq_results, seq_wall)] + \
        [(f"lanes={W_eff},D={D}", D, W_eff, results, wall)
         for D, W_eff, results, wall in lane_runs]
    rows = []
    for mode, D, W_eff, results, wall in runs:
        rep = allocation_report(results, fwd)
        # the lane scheduler must serve identical per-request work at
        # every width and device count (guaranteed in per_sample mode;
        # batch mode couples lanes by design)
        mismatches = sum(a.accepts != b.accepts
                         for a, b in zip(seq_results, results))
        rows.append({
            "mode": mode,
            "devices": D,
            "lanes": W_eff,
            "requests": len(results),
            "wall_s": round(wall, 2),
            "req_per_s": round(len(results) / wall, 3),
            "alpha_mean": round(rep["alpha_mean"], 4),
            "frac_easy": round(rep["frac_easy"], 3),
            "frac_hard": round(rep["frac_hard"], 3),
            "speedup_easy": round(rep["speedup_easy"], 3),
            "speedup_hard": round(rep["speedup_hard"], 3),
            "speedup_all": round(rep["speedup_all"], 3),
            "serving_speedup": round(seq_wall / wall, 3),
            "trajectory_mismatches": mismatches,
        })

    print_table(f"serve_throughput ({args.model}, "
                f"accept_mode={args.accept_mode})", rows)
    for row in rows[1:]:
        print(f"{row['mode']}: {row['serving_speedup']}x requests/s vs "
              f"batch=1, {row['trajectory_mismatches']} trajectory "
              "mismatches")
    path = write_result(f"serve_throughput_{args.model}", rows)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
