"""Serving throughput: sequential batch=1 vs per-lane batched scheduling.

Reports requests/s for both modes plus the Table-2-style sample-adaptive
allocation split (paper §1: 57.5% of samples at 6.48x / 42.5% at lower
acceleration): requests are bucketed at the median acceptance rate into
easy/hard and each bucket's realised FLOPs speedup is shown. Because the
lane scheduler reproduces the exact batch=1 accept trajectories, the two
modes serve identical work — the requests/s delta is pure scheduling.

Run (repo root must be on the path for ``benchmarks.common``):
  PYTHONPATH=src:. python benchmarks/serve_throughput.py \
      --requests 12 --lanes 4 --steps 30
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp

from benchmarks.common import get_model, print_table, write_result
from repro.configs import SpeCaConfig
from repro.core.complexity import forward_flops
from repro.serving import Request, SpeCaEngine, allocation_report


def make_requests(cfg, n: int, *, offset: int = 0):
    return [Request(request_id=offset + i,
                    cond={"labels": jnp.asarray([i % cfg.num_classes])},
                    seed=offset + i)
            for i in range(n)]


def bench(engine: SpeCaEngine, requests, *, lanes: int):
    t0 = time.time()
    results = engine.serve(requests, lanes=lanes)
    wall = time.time() - t0
    return results, wall


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dit", choices=["dit", "flux"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--tau0", type=float, default=0.4)
    ap.add_argument("--accept-mode", default="per_sample",
                    choices=["per_sample", "batch"])
    args = ap.parse_args()

    cfg, dcfg, params = get_model(args.model)
    import dataclasses
    dcfg = dataclasses.replace(dcfg, num_inference_steps=args.steps)
    scfg = SpeCaConfig(taylor_order=2, max_draft=8, tau0=args.tau0,
                       beta=0.9)
    engine = SpeCaEngine(cfg, params, dcfg, scfg,
                         accept_mode=args.accept_mode)

    # warm both paths so compile time stays out of the measurement
    cond0 = {"labels": jnp.asarray([0])}
    engine.warmup(cond0, lanes=1)
    engine.warmup(cond0, lanes=min(args.lanes, args.requests))

    reqs = make_requests(cfg, args.requests)
    seq_results, seq_wall = bench(engine, reqs, lanes=1)
    lane_results, lane_wall = bench(engine, reqs, lanes=args.lanes)

    n_tok = (dcfg.latent_size // cfg.patch_size) ** 2 \
        * max(dcfg.num_frames, 1)
    fwd = forward_flops(cfg, n_tok)
    rows = []
    for mode, results, wall in [("batch=1", seq_results, seq_wall),
                                (f"lanes={args.lanes}", lane_results,
                                 lane_wall)]:
        rep = allocation_report(results, fwd)
        rows.append({
            "mode": mode,
            "requests": len(results),
            "wall_s": round(wall, 2),
            "req_per_s": round(len(results) / wall, 3),
            "alpha_mean": round(rep["alpha_mean"], 4),
            "frac_easy": round(rep["frac_easy"], 3),
            "frac_hard": round(rep["frac_hard"], 3),
            "speedup_easy": round(rep["speedup_easy"], 3),
            "speedup_hard": round(rep["speedup_hard"], 3),
            "speedup_all": round(rep["speedup_all"], 3),
        })
    # the lane scheduler must serve identical per-request work
    # (guaranteed in per_sample mode; batch mode couples lanes by design)
    mismatches = sum(a.accepts != b.accepts
                     for a, b in zip(seq_results, lane_results))
    for row in rows:
        row["serving_speedup"] = round(seq_wall / lane_wall, 3) \
            if row is rows[1] else 1.0
        row["trajectory_mismatches"] = mismatches if row is rows[1] else 0

    print_table(f"serve_throughput ({args.model}, "
                f"accept_mode={args.accept_mode})", rows)
    print(f"\nlane-batched serving: {rows[1]['serving_speedup']}x requests/s"
          f" vs batch=1, {mismatches} trajectory mismatches")
    path = write_result(f"serve_throughput_{args.model}", rows)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
