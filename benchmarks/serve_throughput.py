"""Serving throughput: sequential batch=1 vs per-lane batched scheduling.

Reports requests/s for both modes plus the Table-2-style sample-adaptive
allocation split (paper §1: 57.5% of samples at 6.48x / 42.5% at lower
acceleration): requests are bucketed at the median acceptance rate into
easy/hard and each bucket's realised FLOPs speedup is shown. Because the
lane scheduler reproduces the exact batch=1 accept trajectories, the two
modes serve identical work — the requests/s delta is pure scheduling.

``--workload diffusion,decode,mixed`` selects WHICH traffic is served
(workload-agnostic lane core, docs/llm_serving.md). Every row carries a
``workload`` column. ``decode`` serves LLM self-speculative decode lanes
(``DecodeWorkload`` over a small cached LM) twice — once at
``--decode-tau0`` and once reject-always (τ0=0, plain greedy decoding) —
so the artifact tracks the decode accept rate AND the FLOPs win of
self-speculation over always-full decoding (``tok_per_s`` is the decode
throughput column). ``mixed`` serves diffusion and decode requests
through ONE engine concurrently and reports one row per workload with
per-workload accept rates — the CI liveness signal that heterogeneous
traffic shares the engine without perturbing either side.

``--devices 1,2,4`` adds one lane-scheduler row per device count D: the
engine lane-shards over a D-device ``('data',)`` mesh (requests/s per
device count is the CI artifact column tracking how serving capacity
scales with the mesh). The process must see max(D) devices — on CPU set
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` first.

``--guidance-scale S`` (S>0) benchmarks classifier-free-guidance serving:
every run serves cond/uncond lane PAIRS with one verify decision per pair
(docs/cfg.md), and one extra ``split`` row serves the same work as
2×requests *independent* unguided lanes — the cond and uncond streams as
separate requests, each verifying on its own. ``req_per_s`` counts USER
requests on both rows (a split "request" is half a user request), so the
paired-vs-split delta is the structural win of one decision per pair:
the split streams reject independently, so the union of their rejections
forces more full forwards for the same guided work. Every JSON row
carries a ``guidance`` column (0.0 = unguided) so the perf-trajectory
artifact can chart guided vs unguided requests/s across PRs.

``--draft-depth 1,3`` adds two rows per depth K (deep speculation,
docs/serving.md): a ``depth=K`` row serving the full workload with
per-request ``RequestPolicy(draft_depth=K)`` on a ``max_draft_depth=K``
engine, and a ``depth=K,easy`` row serving only the EASY half of the
workload (requests at or above the median depth-1 acceptance rate —
exactly where chains run long, so where γ>1 drafting pays). Every row
reports ``draft_accept_rate`` = Σ accepted drafted steps / Σ drafted
steps — accounted PER DRAFTED STEP, so a depth-K chain that verifies
once still counts K drafted steps and depths compare honestly. The win
condition tracked by CI: ``depth=3,easy`` requests/s beats
``depth=1,easy``.

``--forecaster taylor,spectral`` adds one row per forecaster family
(pluggable forecasters, docs/forecasters.md): the same diffusion
workload served by an engine compiled with that forecaster, with
per-drafted-step accept rate and total served GFLOPs columns — the CI
artifact tracks what the spectral frequency-band basis buys over the
Taylor difference table at identical τ0 and width.

``--scheduler fifo,sjf,edf`` adds one row per admission scheduler
(serving API v2) serving a MIXED-LENGTH workload: long full-schedule
requests alternating with short ``max_steps=steps/4`` requests that
carry tight deadlines. Scheduling reorders admission only — per-request
trajectories are untouched — so the rows isolate the pure policy win:
``mean_completion_ticks`` (SJF < FIFO on any such workload: shortest-
job-first is completion-time optimal) and ``deadline_hit_rate``
(EDF > FIFO: earliest-deadline-first serves the tight-deadline shorts
before the deadline-less longs that FIFO lets block them).

Run (repo root must be on the path for ``benchmarks.common``):
  PYTHONPATH=src:. python benchmarks/serve_throughput.py \
      --requests 12 --lanes 4 --steps 30
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src:. python benchmarks/serve_throughput.py \
      --requests 8 --lanes 4 --steps 12 --devices 1,2,4
  PYTHONPATH=src:. python benchmarks/serve_throughput.py \
      --requests 8 --lanes 4 --steps 12 --guidance-scale 4.0
  PYTHONPATH=src:. python benchmarks/serve_throughput.py \
      --requests 8 --lanes 2 --steps 12 --scheduler fifo,sjf,edf
  PYTHONPATH=src:. python benchmarks/serve_throughput.py \
      --requests 4 --lanes 2 --steps 12 --workload diffusion,decode,mixed
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (get_lm_model, get_model, print_table,
                               write_result)
from repro.configs import SpeCaConfig
from repro.core.complexity import forward_flops
from repro.diffusion.pipeline import null_cond_like
from repro.launch.mesh import make_lane_mesh
from repro.serving import (DecodeWorkload, Request, RequestPolicy,
                           SpeCaEngine, allocation_report)

# one shared column schema across diffusion/decode/mixed rows so the
# printed table and the artifact JSON stay rectangular (print_table
# takes its header from the first row)
ROW_COLS = ("mode", "workload", "devices", "lanes", "guidance",
            "scheduler", "draft_depth", "forecaster", "requests", "wall_s",
            "req_per_s", "tok_per_s", "alpha_mean", "draft_accept_rate",
            "gflops", "frac_easy", "frac_hard", "speedup_easy",
            "speedup_hard", "speedup_all", "serving_speedup",
            "trajectory_mismatches", "mean_completion_ticks",
            "deadline_hit_rate")


def _row(**kw):
    row = {c: None for c in ROW_COLS}
    row.update({"workload": "diffusion", "devices": 1, "guidance": 0.0,
                "scheduler": "fifo", "draft_depth": 1,
                "forecaster": "taylor"})
    unknown = set(kw) - set(ROW_COLS)
    if unknown:
        raise KeyError(f"unknown row columns: {sorted(unknown)}")
    row.update(kw)
    return row


def make_requests(cfg, n: int, *, offset: int = 0, guidance_scale=None):
    return [Request(request_id=offset + i,
                    cond={"labels": jnp.asarray([i % cfg.num_classes])},
                    seed=offset + i, guidance_scale=guidance_scale)
            for i in range(n)]


def decode_requests(lm_cfg, n: int, prompt_len: int, *, tau0: float,
                    offset: int = 0, max_steps=None):
    """Decode-workload traffic: each request carries a distinct random
    prompt and a per-request τ0 policy (τ0=0 → reject-always greedy)."""
    out = []
    for i in range(n):
        prompt = np.asarray(
            jax.random.randint(jax.random.PRNGKey(offset + i),
                               (1, prompt_len), 0, lm_cfg.vocab_size),
            np.int32)
        out.append(Request(
            request_id=offset + i, cond={"tokens": prompt},
            seed=offset + i,
            policy=RequestPolicy(workload="decode", tau0=tau0,
                                 max_steps=max_steps)))
    return out


def deadline_workload(cfg, n: int, steps: int, lanes: int):
    """Mixed-length workload for the scheduler comparison: even indices
    are long full-schedule requests (no deadline), odd indices are short
    ``steps//4`` requests whose deadline is feasible when served ahead
    of the longs (k-th short: ceil(k/lanes)·short + steps/2 ticks) but
    blown as soon as FIFO parks them behind a long request. Completion
    ticks depend only on admission order and schedule lengths — never on
    accept decisions — so the scheduler deltas below are deterministic.
    """
    short = max(steps // 4, 1)
    reqs, k = [], 0
    for i in range(n):
        pol = None
        if i % 2 == 1:
            k += 1
            dl = float(-(-k // max(lanes, 1)) * short + steps // 2)
            pol = RequestPolicy(max_steps=short, deadline=dl)
        reqs.append(Request(
            request_id=i,
            cond={"labels": jnp.asarray([i % cfg.num_classes])},
            seed=i, policy=pol))
    return reqs


def sched_stats(results):
    """(mean completion ticks, deadline hit rate | None)."""
    ticks = [r.finish_tick for r in results if r.finish_tick is not None]
    met = [r.deadline_met for r in results if r.deadline is not None]
    mean_ticks = sum(ticks) / max(len(ticks), 1)
    hit = sum(bool(m) for m in met) / len(met) if met else None
    return mean_ticks, hit


def split_requests(cfg, guided_requests):
    """The two-independent-streams baseline: each guided request becomes
    a conditional AND an unconditional unguided request sharing its seed
    (same noise), so the same model work is served — but every stream
    verifies and accepts on its own, with no pair coupling."""
    out = []
    for r in guided_requests:
        out.append(Request(request_id=2 * r.request_id, cond=r.cond,
                           seed=r.seed))
        out.append(Request(request_id=2 * r.request_id + 1,
                           cond=null_cond_like(cfg, r.cond), seed=r.seed))
    return out


def bench(engine: SpeCaEngine, requests, *, lanes: int):
    t0 = time.time()
    results = engine.serve(requests, lanes=lanes)
    wall = time.time() - t0
    return results, wall


def draft_accept_rate(results) -> float:
    """Workload-level PER-DRAFTED-STEP acceptance: Σ accepted drafted
    steps over Σ drafted chain positions. One depth-K chain contributes
    K drafted steps to the denominator — counting it as one verify
    would let deep runs inflate the rate."""
    spec = sum(r.num_spec for r in results)
    drafted = sum(r.num_drafted for r in results)
    return spec / max(drafted, 1)


def _rep_cols(rep):
    return dict(
        alpha_mean=round(rep["alpha_mean"], 4),
        frac_easy=round(rep["frac_easy"], 3),
        frac_hard=round(rep["frac_hard"], 3),
        speedup_easy=round(rep["speedup_easy"], 3),
        speedup_hard=round(rep["speedup_hard"], 3),
        speedup_all=round(rep["speedup_all"], 3))


def run_diffusion(args, model):
    """The diffusion serving benchmark (sequential vs lanes, devices,
    CFG pairs, draft depths, schedulers). Returns the artifact rows."""
    cfg, dcfg, params = model
    device_counts = sorted({int(d) for d in args.devices.split(",")})
    guided = args.guidance_scale > 0
    gs = args.guidance_scale if guided else None
    streams = 2 if guided else 1
    scfg = SpeCaConfig(taylor_order=2, max_draft=8, tau0=args.tau0,
                       beta=0.9)

    def make_engine(D: int, *, guidance: bool = guided,
                    depth: int = 1) -> SpeCaEngine:
        return SpeCaEngine(cfg, params, dcfg, scfg,
                           accept_mode=args.accept_mode,
                           guidance=guidance, max_draft_depth=depth,
                           mesh=make_lane_mesh(D) if D > 1 else None)

    cond0 = {"labels": jnp.asarray([0])}
    reqs = make_requests(cfg, args.requests, guidance_scale=gs)
    lane_cap = min(args.lanes, streams * args.requests)
    engine = make_engine(1)
    # warm both paths so compile time stays out of the measurement
    engine.warmup(cond0, lanes=streams)
    engine.warmup(cond0, lanes=lane_cap)
    seq_results, seq_wall = bench(engine, reqs, lanes=streams)

    # one lane-scheduler run per device count (D=1: plain engine; D>1:
    # the lane axis sharded over a D-device ('data',) mesh). The row is
    # labeled with the EFFECTIVE lane width — a mesh engine rounds the
    # width up to a multiple of D, so requesting --lanes 2 on D=4 serves
    # 4 lanes; hiding that would let a pure width gain masquerade as
    # device scaling in the per-device-count column.
    lane_runs = []
    for D in device_counts:
        eng = engine if D == 1 else make_engine(D)
        if D > 1:
            eng.warmup(cond0, lanes=lane_cap)
        W_eff = eng.lane_width(args.lanes, len(reqs))
        results, wall = bench(eng, reqs, lanes=args.lanes)
        lane_runs.append((D, W_eff, results, wall))

    # split baseline (guided only): the same guided work as 2×requests
    # independent unguided lanes — cond and uncond streams decoupled, two
    # verify decisions where the paired engine takes one
    split_run = None
    if guided:
        split_engine = make_engine(1, guidance=False)
        split_reqs = split_requests(cfg, reqs)
        split_engine.warmup(cond0, lanes=min(args.lanes, len(split_reqs)))
        split_results, split_wall = bench(split_engine, split_reqs,
                                          lanes=args.lanes)
        split_run = (split_engine.lane_width(args.lanes, len(split_reqs)),
                     split_results, split_wall)

    n_tok = (dcfg.latent_size // cfg.patch_size) ** 2 \
        * max(dcfg.num_frames, 1)
    fwd = forward_flops(cfg, n_tok)
    seq_mode = f"batch=1{',paired' if guided else ''}"
    runs = [(seq_mode, 1, streams, seq_results, seq_wall, streams * fwd)] \
        + [(f"lanes={W_eff},D={D}{',paired' if guided else ''}", D, W_eff,
            results, wall, streams * fwd)
           for D, W_eff, results, wall in lane_runs]
    if split_run is not None:
        W_eff, split_results, split_wall = split_run
        runs.append((f"lanes={W_eff},D=1,split", 1, W_eff, split_results,
                     split_wall, fwd))
    rows = []
    for mode, D, W_eff, results, wall, fwd_ref in runs:
        rep = allocation_report(results, fwd_ref)
        split = mode.endswith(",split")
        # the lane scheduler must serve identical per-request work at
        # every width and device count (guaranteed in per_sample mode;
        # batch mode couples lanes by design). The split row serves
        # different work by construction (independent stream decisions),
        # so its mismatch count is meaningless and reported as None.
        mismatches = None if split else \
            sum(a.accepts != b.accepts
                for a, b in zip(seq_results, results))
        # req_per_s counts USER requests: a split row's 2N stream
        # requests serve N user requests' work
        n_user = len(results) // (2 if split else 1)
        mean_ticks, hit = sched_stats(results)
        rows.append(_row(
            mode=mode, devices=D, lanes=W_eff,
            guidance=args.guidance_scale if guided else 0.0,
            requests=n_user,
            wall_s=round(wall, 2),
            req_per_s=round(n_user / wall, 3),
            draft_accept_rate=round(draft_accept_rate(results), 4),
            serving_speedup=round(seq_wall / wall, 3),
            trajectory_mismatches=mismatches,
            mean_completion_ticks=round(mean_ticks, 2),
            deadline_hit_rate=hit,
            **_rep_cols(rep)))

    # scheduler comparison (serving API v2): one row per admission
    # policy, same engine, same mixed-length deadline workload — the
    # deltas are pure admission-order policy (docs/serving.md)
    sched_names = [s for s in args.scheduler.split(",") if s]
    sched_rows = []
    if sched_names:
        # the comparison workload is unguided — guidance changes lane
        # occupancy, not admission order, and the guided rows above
        # already track the pairing win
        wl = deadline_workload(cfg, args.requests, args.steps, args.lanes)
        sched_engine = make_engine(1, guidance=False)
        sched_engine.warmup(cond0, lanes=args.lanes)
        for name in sched_names:
            t0 = time.time()
            results = sched_engine.serve_batched(wl, lanes=args.lanes,
                                                 scheduler=name)
            wall = time.time() - t0
            # the comparison workload is unguided regardless of
            # --guidance-scale: unguided step cost and guidance=0.0
            rep = allocation_report(results, fwd)
            mean_ticks, hit = sched_stats(results)
            row = _row(
                mode=f"sched={name}",
                lanes=sched_engine._width_for(
                    args.lanes, [sched_engine.resolve_policy(r)
                                 for r in wl]),
                scheduler=name,
                requests=len(wl),
                wall_s=round(wall, 2),
                req_per_s=round(len(wl) / wall, 3),
                draft_accept_rate=round(draft_accept_rate(results), 4),
                # the sequential baseline timed a different (all
                # full-length) workload — serving_speedup not comparable
                mean_completion_ticks=round(mean_ticks, 2),
                deadline_hit_rate=hit,
                **_rep_cols(rep))
            sched_rows.append(row)
            rows.append(row)

    # deep-speculation comparison (--draft-depth): per depth K one
    # full-workload row and one row serving only the EASY bucket
    # (requests at/above the median depth-1 acceptance rate — long
    # accept runs, where a K-step chain replaces K scheduler ticks).
    # All depth engines run at D=1 with per-request draft_depth
    # policies; accept rates are per DRAFTED step on every row.
    depths = sorted({int(d) for d in args.draft_depth.split(",") if d})
    depth_rows = []
    if depths and depths != [1]:
        alphas = sorted(r.alpha for r in seq_results)
        med = alphas[len(alphas) // 2]
        easy_ids = {r.request_id for r in seq_results if r.alpha >= med}
        for K in depths:
            deng = make_engine(1, depth=K)
            deng.warmup(cond0, lanes=lane_cap)
            easy_cap = min(args.lanes, streams * len(easy_ids))
            if easy_cap != lane_cap:
                deng.warmup(cond0, lanes=easy_cap)
            pol = RequestPolicy(draft_depth=K)
            dreqs = [dataclasses.replace(r, policy=pol) for r in reqs]
            for tag, subset in (
                    ("", dreqs),
                    (",easy", [r for r in dreqs
                               if r.request_id in easy_ids])):
                results, wall = bench(deng, subset, lanes=args.lanes)
                rep = allocation_report(results, streams * fwd)
                mean_ticks, hit = sched_stats(results)
                mismatches = None if tag else sum(
                    a.accepts != b.accepts
                    for a, b in zip(seq_results, results))
                row = _row(
                    mode=f"depth={K}{tag}",
                    lanes=deng.lane_width(args.lanes, len(subset)),
                    guidance=args.guidance_scale if guided else 0.0,
                    draft_depth=K,
                    requests=len(subset),
                    wall_s=round(wall, 2),
                    req_per_s=round(len(subset) / wall, 3),
                    draft_accept_rate=round(draft_accept_rate(results),
                                            4),
                    # the easy row serves half the workload — not
                    # comparable to the sequential full-workload wall
                    serving_speedup=None if tag
                    else round(seq_wall / wall, 3),
                    trajectory_mismatches=mismatches,
                    mean_completion_ticks=round(mean_ticks, 2),
                    deadline_hit_rate=hit,
                    **_rep_cols(rep))
                depth_rows.append(row)
                rows.append(row)

    for row in rows[1:]:
        if row["mode"].startswith(("sched=", "depth=")):
            continue
        line = (f"{row['mode']}: {row['serving_speedup']}x requests/s "
                f"vs {seq_mode}")
        if row["trajectory_mismatches"] is not None:
            line += (f", {row['trajectory_mismatches']} trajectory "
                     "mismatches")
        print(line)
    if depth_rows:
        by_depth_easy = {r["draft_depth"]: r for r in depth_rows
                         if r["mode"].endswith(",easy")}
        for r in depth_rows:
            print(f"{r['mode']}: {r['req_per_s']} req/s, "
                  f"accept/drafted {r['draft_accept_rate']}")
        if 1 in by_depth_easy:
            base = by_depth_easy[1]["req_per_s"]
            for K in sorted(by_depth_easy):
                if K == 1:
                    continue
                ratio = by_depth_easy[K]["req_per_s"] / max(base, 1e-9)
                print(f"depth={K} vs depth=1 easy-bucket requests/s: "
                      f"{ratio:.2f}x")
    if sched_rows:
        by_name = {r["scheduler"]: r for r in sched_rows}
        for r in sched_rows:
            hit = "n/a" if r["deadline_hit_rate"] is None \
                else f"{r['deadline_hit_rate']:.2f}"
            print(f"sched={r['scheduler']}: mean completion "
                  f"{r['mean_completion_ticks']} ticks, deadline hit "
                  f"rate {hit}")
        if "fifo" in by_name:
            f = by_name["fifo"]
            if "sjf" in by_name:
                print(f"sjf vs fifo mean completion ticks: "
                      f"{by_name['sjf']['mean_completion_ticks']} vs "
                      f"{f['mean_completion_ticks']}")
            if "edf" in by_name and f["deadline_hit_rate"] is not None:
                print(f"edf vs fifo deadline hit rate: "
                      f"{by_name['edf']['deadline_hit_rate']:.2f} vs "
                      f"{f['deadline_hit_rate']:.2f}")
    if guided and split_run is not None:
        # the split baseline always runs at D=1, so compare it against
        # the D=1 paired row specifically — with --devices 2,4 the first
        # lane row is a multi-device run and would conflate mesh scaling
        # with the one-decision-per-pair win
        paired = next((r for r in rows
                       if r["devices"] == 1 and r["mode"].endswith(
                           ",paired") and not r["mode"].startswith(
                           "batch=1")), None)
        split_row = next(r for r in rows if r["mode"].endswith(",split"))
        if paired is not None:
            ratio = paired["req_per_s"] / max(split_row["req_per_s"],
                                              1e-9)
            print(f"paired vs split (cond+uncond as independent lanes): "
                  f"{ratio:.2f}x requests/s")
    return rows


def run_forecasters(args, model):
    """Forecaster comparison (``--forecaster taylor,spectral``): one row
    per forecaster family serving the SAME diffusion workload on its own
    engine — the Taylor difference table vs the spectral frequency-band
    ring (docs/forecasters.md).  The tracked columns: per-drafted-step
    accept rate and total served GFLOPs, so the artifact shows what each
    extrapolation basis buys (or costs) at identical τ0/width."""
    cfg, dcfg, params = model
    scfg = SpeCaConfig(taylor_order=2, max_draft=8, tau0=args.tau0,
                       beta=0.9)
    names = [f for f in args.forecaster.split(",") if f]
    reqs = make_requests(cfg, args.requests)
    cond0 = {"labels": jnp.asarray([0])}
    rows = []
    n_tok = (dcfg.latent_size // cfg.patch_size) ** 2 \
        * max(dcfg.num_frames, 1)
    fwd = forward_flops(cfg, n_tok)
    for name in names:
        eng = SpeCaEngine(cfg, params, dcfg, scfg,
                          accept_mode=args.accept_mode, forecaster=name)
        eng.warmup(cond0, lanes=min(args.lanes, args.requests))
        results, wall = bench(eng, reqs, lanes=args.lanes)
        rep = allocation_report(results, fwd)
        mean_ticks, _ = sched_stats(results)
        rows.append(_row(
            mode=f"forecaster={name}", forecaster=name,
            lanes=eng.lane_width(args.lanes, len(reqs)),
            requests=len(reqs),
            wall_s=round(wall, 2),
            req_per_s=round(len(reqs) / wall, 3),
            draft_accept_rate=round(draft_accept_rate(results), 4),
            gflops=round(sum(r.flops for r in results) / 1e9, 3),
            mean_completion_ticks=round(mean_ticks, 2),
            **_rep_cols(rep)))
        print(f"forecaster={name}: accept/drafted "
              f"{rows[-1]['draft_accept_rate']}, "
              f"{rows[-1]['gflops']} GFLOPs, "
              f"{rows[-1]['req_per_s']} req/s")
    return rows


def run_decode(args, lm):
    """LLM decode lanes: one engine, two request batches — speculative
    (τ0 = --decode-tau0) and reject-always (τ0 = 0, exact greedy
    decoding) — served at identical lane widths. The tracked win:
    accept rate > 0 AND fewer total FLOPs than reject-always for the
    same emitted tokens-per-request."""
    lm_cfg, lm_params = lm
    wl = DecodeWorkload(lm_cfg, lm_params,
                        SpeCaConfig(tau0=args.decode_tau0),
                        max_new_tokens=args.gen_len,
                        max_seq_len=args.prompt_len + args.gen_len)
    eng = SpeCaEngine(workloads={"decode": wl}, lanes=args.lanes)
    warm = decode_requests(lm_cfg, 1, args.prompt_len,
                           tau0=args.decode_tau0, offset=90_000)[0]
    eng.warmup(warm.cond, lanes=min(args.lanes, args.requests),
               workload="decode")

    rows, flops = [], {}
    for mode, tau0 in (("decode", args.decode_tau0),
                       ("decode,reject", 0.0)):
        reqs = decode_requests(lm_cfg, args.requests, args.prompt_len,
                               tau0=tau0)
        t0 = time.time()
        results = eng.serve_batched(reqs, lanes=args.lanes)
        wall = time.time() - t0
        rep = allocation_report(results, wl.full_flops)
        flops[mode] = sum(r.flops for r in results)
        mean_ticks, _ = sched_stats(results)
        rows.append(_row(
            mode=mode, workload="decode",
            lanes=eng.lane_width(args.lanes, len(reqs)),
            requests=len(reqs),
            wall_s=round(wall, 2),
            req_per_s=round(len(reqs) / wall, 3),
            tok_per_s=round(len(reqs) * args.gen_len / wall, 1),
            draft_accept_rate=round(draft_accept_rate(results), 4),
            mean_completion_ticks=round(mean_ticks, 2),
            **_rep_cols(rep)))
    spec_row = rows[0]
    ratio = flops["decode,reject"] / max(flops["decode"], 1e-9)
    print(f"decode: accept rate {spec_row['alpha_mean']}, "
          f"{flops['decode'] / 1e9:.3f} GFLOPs vs "
          f"{flops['decode,reject'] / 1e9:.3f} reject-always "
          f"({ratio:.2f}x fewer FLOPs)")
    return rows


def run_mixed(args, model, lm):
    """Diffusion + decode traffic interleaved through ONE engine (one
    scheduler, per-workload sessions). One row per workload with that
    side's accept rate; ``wall_s`` is the SHARED wall of the whole
    mixed batch, so the per-row req/s reflect concurrent service."""
    cfg, dcfg, params = model
    lm_cfg, lm_params = lm
    scfg = SpeCaConfig(taylor_order=2, max_draft=8, tau0=args.tau0,
                       beta=0.9)
    wl = DecodeWorkload(lm_cfg, lm_params,
                        SpeCaConfig(tau0=args.decode_tau0),
                        max_new_tokens=args.gen_len,
                        max_seq_len=args.prompt_len + args.gen_len)
    eng = SpeCaEngine(cfg, params, dcfg, scfg,
                      workloads={"decode": wl}, lanes=args.lanes)
    n = args.requests
    dreqs = make_requests(cfg, n)
    treqs = decode_requests(lm_cfg, n, args.prompt_len,
                            tau0=args.decode_tau0, offset=1000)
    # warm both per-tag slot programs at the widths the timed batch will
    # use (same per-tag request counts → same _width_for result); the
    # warm requests run truncated 2-step schedules — compilation depends
    # on width and tag, not schedule length
    k = min(args.lanes, n)
    warm = [dataclasses.replace(r, request_id=-1 - i,
                                policy=RequestPolicy(max_steps=2))
            for i, r in enumerate(dreqs[:k])] \
        + decode_requests(lm_cfg, k, args.prompt_len,
                          tau0=args.decode_tau0, offset=91_000,
                          max_steps=2)
    eng.serve_batched(warm, lanes=args.lanes)

    reqs = [r for pair in zip(dreqs, treqs) for r in pair]
    t0 = time.time()
    results = eng.serve_batched(reqs, lanes=args.lanes)
    wall = time.time() - t0
    n_tok = (dcfg.latent_size // cfg.patch_size) ** 2 \
        * max(dcfg.num_frames, 1)
    fwd_ref = {"diffusion": forward_flops(cfg, n_tok),
               "decode": wl.full_flops}
    rows = []
    for tag in ("diffusion", "decode"):
        rs = [r for r in results if r.workload == tag]
        rep = allocation_report(rs, fwd_ref[tag])
        mean_ticks, _ = sched_stats(rs)
        rows.append(_row(
            mode=f"mixed,{tag}", workload=tag,
            lanes=eng.lane_width(args.lanes, len(rs)),
            requests=len(rs),
            wall_s=round(wall, 2),
            req_per_s=round(len(rs) / wall, 3),
            tok_per_s=round(len(rs) * args.gen_len / wall, 1)
            if tag == "decode" else None,
            draft_accept_rate=round(draft_accept_rate(rs), 4),
            mean_completion_ticks=round(mean_ticks, 2),
            **_rep_cols(rep)))
    print(f"mixed: diffusion accept {rows[0]['alpha_mean']}, "
          f"decode accept {rows[1]['alpha_mean']} — "
          f"{len(results)} requests through one engine in {wall:.2f}s")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dit", choices=["dit", "flux"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--tau0", type=float, default=0.4)
    ap.add_argument("--accept-mode", default="per_sample",
                    choices=["per_sample", "batch"])
    ap.add_argument("--workload", default="diffusion",
                    help="comma list of traffic kinds to serve: "
                         "diffusion, decode (LLM self-speculative "
                         "lanes, spec vs reject-always rows), mixed "
                         "(both kinds through one engine)")
    ap.add_argument("--lm-arch", default="mamba2-130m",
                    help="registry arch of the decode-workload LM")
    ap.add_argument("--decode-tau0", type=float, default=5.0,
                    help="verification threshold of the decode rows "
                         "(the reject-always baseline always runs τ0=0)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16,
                    help="new tokens per decode request")
    ap.add_argument("--guidance-scale", type=float, default=0.0,
                    help=">0: classifier-free-guidance serving (paired "
                         "cond/uncond lanes) plus a split baseline row "
                         "serving the streams as independent requests")
    ap.add_argument("--forecaster", default="",
                    help="comma list of forecaster families to compare "
                         "on the diffusion workload, e.g. taylor,"
                         "spectral (adds one row per forecaster with "
                         "accept-rate and GFLOPs columns)")
    ap.add_argument("--draft-depth", default="1",
                    help="comma list of draft horizons, e.g. 1,3: adds a "
                         "full-workload row and an easy-bucket row per "
                         "depth K>0 beyond the base depth-1 rows")
    ap.add_argument("--devices", default="1",
                    help="comma list of lane-shard device counts, e.g. "
                         "1,2,4 (needs that many visible devices)")
    ap.add_argument("--scheduler", default="",
                    help="comma list of admission schedulers to compare "
                         "on a mixed-length deadline workload, e.g. "
                         "fifo,sjf,edf (adds one row per scheduler)")
    args = ap.parse_args()
    wls = []
    for w in args.workload.split(","):
        w = w.strip()
        if w and w not in wls:
            wls.append(w)
    unknown = set(wls) - {"diffusion", "decode", "mixed"}
    if unknown or not wls:
        ap.error(f"--workload must name diffusion/decode/mixed, got "
                 f"{args.workload!r}")
    guided = args.guidance_scale > 0

    model = None
    if "diffusion" in wls or "mixed" in wls:
        cfg, dcfg, params = get_model(args.model)
        dcfg = dataclasses.replace(dcfg, num_inference_steps=args.steps)
        model = (cfg, dcfg, params)
    lm = get_lm_model(args.lm_arch) \
        if "decode" in wls or "mixed" in wls else None

    rows = []
    if "diffusion" in wls:
        rows += run_diffusion(args, model)
        if args.forecaster:
            rows += run_forecasters(args, model)
    if "decode" in wls:
        rows += run_decode(args, lm)
    if "mixed" in wls:
        rows += run_mixed(args, model, lm)

    print_table(f"serve_throughput ({args.model}, "
                f"accept_mode={args.accept_mode}"
                + (f", guidance={args.guidance_scale}" if guided else "")
                + (f", workload={'+'.join(wls)}"
                   if wls != ["diffusion"] else "")
                + ")", rows)
    suffix = "_cfg" if guided and "diffusion" in wls else ""
    if wls != ["diffusion"]:
        suffix += "".join(f"_{w}" for w in wls if w != "diffusion")
    path = write_result(f"serve_throughput_{args.model}{suffix}", rows)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
