"""Fig. 2 (quality vs acceleration), Fig. 6 (layer correlation), and the
Appendix-C trajectory analysis."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.configs import SpeCaConfig
from repro.core import taylor
from repro.core.speca import speca_sample
from repro.core.verify import relative_error
from repro.diffusion.pipeline import (latent_shape, make_stepper,
                                      model_inputs, sample_full)
from repro.layers import model as M


def fig2_quality_curve(batch=16):
    """Quality (FID-proxy) vs acceleration for SpeCa and baselines."""
    cfg, dcfg, params = C.get_model("dit")
    cond = C.make_cond(cfg, dcfg, batch)
    key = jax.random.PRNGKey(2)
    ref = C.reference_latents(cfg, dcfg, 64)
    tpl = C.class_templates(cfg, dcfg)
    x_full = C.run_method("full", cfg, dcfg, params, cond, batch,
                          key).samples
    rows = []
    sweeps = {
        "speca": ["speca_0.05", "speca_0.1", "speca_0.3", "speca_0.6",
                  "speca_1.0"],
        "taylorseer": ["taylorseer_2_2", "taylorseer_4_2", "taylorseer_7_2",
                       "taylorseer_10_2"],
        "fora": ["fora_2", "fora_4", "fora_7", "fora_10"],
        "steps": ["steps_0.5", "steps_0.25", "steps_0.14", "steps_0.1"],
    }
    for family, methods in sweeps.items():
        for name in methods:
            res = C.run_method(name, cfg, dcfg, params, cond, batch, key)
            row = C.evaluate(res, x_full, cfg, dcfg, cond, tpl, ref)
            row["family"] = family
            rows.append(row)
    C.print_table("fig2_quality_vs_acceleration", rows)
    C.write_result("fig2_quality_curve", rows)
    return rows


def fig6_layer_correlation(batch=8, interval=4):
    """Correlation between per-layer draft errors and final-output error.

    Replicates the paper's Fig. 6 analysis: deeper layers' activation
    errors correlate best with the final output error, justifying deep
    verification (r=0.842 at layer 27 in the paper)."""
    cfg, dcfg, params = C.get_model("dit")
    cond = C.make_cond(cfg, dcfg, batch)
    key = jax.random.PRNGKey(4)
    stepper = make_stepper(dcfg)
    L = cfg.num_layers
    n_tok = (dcfg.latent_size // cfg.patch_size) ** 2

    x = jax.random.normal(key, latent_shape(cfg, dcfg, batch), jnp.float32)
    feat_shape = taylor.feature_shape_for(L, batch, n_tok, cfg.d_model)
    tstate = taylor.init_state(2, feat_shape, cfg.jnp_dtype)

    fwd = jax.jit(lambda x, t: M.dit_forward(
        cfg, params, model_inputs(cfg, x, t, cond), collect_branches=True))

    layer_errs = []   # per predicted step: [L, B]
    out_errs = []     # per predicted step: [B]
    for s in range(stepper.num_steps):
        out, ex = fwd(x, stepper.t_model[s])
        warm = int(tstate["n_anchors"]) > 2
        if warm and s % interval != 0:
            preds = taylor.predict(tstate, s)
            # per-layer relative error between predicted and real branches
            errs = []
            for l in range(L):
                pred_l = preds[l][0] + preds[l][1]
                real_l = ex["branches"][l][0] + ex["branches"][l][1]
                errs.append(np.asarray(relative_error(pred_l, real_l)))
            layer_errs.append(np.stack(errs))
            # final-output error: model output from drafted features
            out_spec, _ = M.dit_forward(
                cfg, params, model_inputs(cfg, x, stepper.t_model[s], cond),
                branch_preds=preds,
                compute_mask=jnp.zeros((L,), bool))
            out_errs.append(np.asarray(relative_error(out_spec, out)))
        else:
            tstate = taylor.update(tstate, ex["branches"], s)
        x = stepper.advance(x, out, s)

    layer_errs = np.concatenate(layer_errs, axis=1)  # [L, N]
    out_errs = np.concatenate(out_errs)              # [N]
    rows = []
    for l in range(L):
        r = float(np.corrcoef(layer_errs[l], out_errs)[0, 1])
        rows.append({"layer": l, "pearson_r": round(r, 4)})
    C.print_table("fig6_layer_error_correlation", rows)
    C.write_result("fig6_layer_correlation", rows)
    return rows


def trajectory_analysis(batch=4):
    """Appendix C: PCA trajectories — SpeCa should hug the full-compute
    path while unverified caching drifts."""
    cfg, dcfg, params = C.get_model("dit")
    cond = C.make_cond(cfg, dcfg, batch)
    key = jax.random.PRNGKey(6)

    x_full, traj_full = jax.jit(lambda k: sample_full(
        cfg, params, dcfg, k, cond, batch, collect_trajectory=True))(key)
    from repro.core.baselines import cached_sample, fora, taylorseer
    scfg = SpeCaConfig(taylor_order=2, max_draft=8, tau0=0.4, beta=0.9)
    _, st_sp = jax.jit(lambda k: speca_sample(
        cfg, params, dcfg, scfg, k, cond, batch,
        collect_trajectory=True))(key)
    _, st_fo = jax.jit(lambda k: cached_sample(
        cfg, params, dcfg, fora(5), k, cond, batch,
        collect_trajectory=True))(key)
    _, st_ts = jax.jit(lambda k: cached_sample(
        cfg, params, dcfg, taylorseer(5), k, cond, batch,
        collect_trajectory=True))(key)

    ref = np.asarray(traj_full).reshape(dcfg.num_inference_steps, -1)
    rows = []
    for name, st in [("speca", st_sp), ("taylorseer_5", st_ts),
                     ("fora_5", st_fo)]:
        t = np.asarray(st["trajectory"]).reshape(len(ref), -1)
        per_step = np.linalg.norm(t - ref, axis=1) \
            / (np.linalg.norm(ref, axis=1) + 1e-9)
        rows.append({
            "method": name,
            "mean_traj_dev": round(float(per_step.mean()), 5),
            "final_dev": round(float(per_step[-1]), 5),
            "max_dev": round(float(per_step.max()), 5),
        })
    C.print_table("trajectory_analysis (Appendix C)", rows)
    C.write_result("trajectory_analysis", rows)
    return rows


if __name__ == "__main__":
    fig2_quality_curve()
    fig6_layer_correlation()
    trajectory_analysis()
