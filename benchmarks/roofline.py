"""§Roofline: three-term roofline per (arch × shape) from dry-run artifacts.

  compute term    = HLO_FLOPs/device ÷ 197 TFLOP/s (bf16, v5e)
  memory term     = HLO_bytes/device ÷ 819 GB/s HBM
  collective term = wire_bytes/device ÷ 50 GB/s/link ICI

Everything reads the JSON artifacts produced by ``repro.launch.dryrun``
(single-pod 16×16 for the table; the 2×16×16 pass is a lowering proof).
MODEL_FLOPS = 6·N_active·D (train, fwd+bwd) or 2·N_active·D (inference);
the ratio MODEL_FLOPS/HLO_FLOPs flags remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.configs import get_config
from repro.core.complexity import model_flops_6nd

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s
ICI_BW = 50e9            # B/s per link

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts",
                   "dryrun")


def _advice(dominant: str, rec: Dict) -> str:
    arch = rec["arch"]
    kind = rec["kind"]
    if dominant == "memory":
        if kind == "decode":
            return ("decode is KV/weight-streaming bound: quantise the "
                    "cache to int8/fp8 or shrink windowed layers' caches")
        return ("fuse/block the attention reads (flash kernel) and keep "
                "the residual stream in bf16 to cut HBM traffic")
    if dominant == "collective":
        if "moe" in get_config(arch).arch_type:
            return ("expert-parallel all-to-all dominates: overlap dispatch "
                    "with expert GEMMs or switch to 2D expert+data sharding")
        return ("shrink TP-boundary all-reduces: reduce-scatter + all-gather "
                "(sequence sharding) or overlap collectives with compute")
    return ("compute-bound (good); raise per-chip utilisation via larger "
            "per-device batch or fewer, larger matmuls")


def load_records(mesh: str = "pod16x16") -> List[Dict]:
    """Full-L artifacts, with scan-corrected metrics merged in when the
    calibrated (L=1/L=2 extrapolation) artifact exists — XLA counts a
    scan body once, so the corrected numbers are the real roofline inputs."""
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, f"*_{mesh}.json"))):
        if path.endswith("_cal.json"):
            continue
        with open(path) as f:
            rec = json.load(f)
        cal_path = path.replace(".json", "_cal.json")
        if os.path.exists(cal_path):
            with open(cal_path) as f:
                cal = json.load(f)
            rec["flops_per_device"] = cal["flops_per_device_corrected"]
            rec["bytes_per_device"] = cal["bytes_per_device_corrected"]
            rec["collective_wire_bytes_per_device"] = \
                cal["collective_wire_bytes_corrected"]
            rec["scan_corrected"] = True
        else:
            rec["scan_corrected"] = False
        recs.append(rec)
    return recs


def roofline_rows(mesh: str = "pod16x16") -> List[Dict]:
    rows = []
    for rec in load_records(mesh):
        arch = rec["arch"]
        cfg = get_config(arch)
        t_c = rec["flops_per_device"] / PEAK_FLOPS
        t_m = rec["bytes_per_device"] / HBM_BW
        t_n = rec["collective_wire_bytes_per_device"] / ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_n}
        dominant = max(terms, key=terms.get)
        # useful model FLOPs per device: 6·N_active·tokens for training
        # (fwd+bwd), 2·N_active·tokens for inference forwards
        tokens = rec["seq_len"] * rec["global_batch"] if rec["kind"] != \
            "decode" else rec["global_batch"]
        per_tok = 6.0 if rec["kind"] == "train" else 2.0
        mf = per_tok * cfg.active_param_count() * tokens
        mf_dev = mf / rec["num_devices"]
        ratio = mf_dev / max(rec["flops_per_device"], 1.0)
        rows.append({
            "arch": arch,
            "shape": rec["shape"],
            "kind": rec["kind"],
            "compute_s": f"{t_c:.3e}",
            "memory_s": f"{t_m:.3e}",
            "collective_s": f"{t_n:.3e}",
            "dominant": dominant,
            "bound_s": f"{max(terms.values()):.3e}",
            "model_flops_ratio": f"{ratio:.3f}",
            "temp_GiB": round(rec["memory"]["temp_bytes"] / 2**30, 2),
            "fits_16G": rec["memory"]["temp_bytes"] / 2**30 < 16.0,
            "scan_corrected": rec["scan_corrected"],
            "advice": _advice(dominant, rec),
        })
    return rows


def run(mesh: str = "pod16x16"):
    rows = roofline_rows(mesh)
    from benchmarks import common as C
    C.print_table(f"roofline ({mesh}, v5e constants)", rows)
    C.write_result(f"roofline_{mesh}", rows)
    # interesting-pair selection for the perf loop
    if rows:
        worst = min(rows, key=lambda r: float(r["model_flops_ratio"]))
        coll = max(rows, key=lambda r: float(r["collective_s"]))
        print(f"\nworst model-FLOPs ratio: {worst['arch']} × "
              f"{worst['shape']} ({worst['model_flops_ratio']})")
        print(f"most collective-bound:  {coll['arch']} × {coll['shape']} "
              f"({coll['collective_s']}s)")
    return rows


if __name__ == "__main__":
    run()
