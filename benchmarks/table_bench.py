"""Difference-table hot path: per-step draft latency + table bytes moved.

The SpeCa speedup claim needs the draft path to be nearly free (paper
§3.5: verification overhead 1.67%–3.5%), so the TaylorSeer table
evaluation/refresh must stay memory-lean. This benchmark compares the two
table backends on the serving layout (m+1, L, 2, B, T, D):

  * ``jnp``   — the staged oracle: ``astype(f32)`` whole-table copy +
    einsum for predict; recursive rows + ``stack`` + ``where`` (three
    table-sized materialisations) for the masked refresh.
  * ``kernel`` — the fused lane-masked Pallas kernels: one pass over the
    table, weights/mask applied in registers, no whole-table temporary.

Reported per step and per backend: measured wall latency and the analytic
HBM bytes moved (from the op semantics — what a roofline would charge).
NOTE on CPU this container executes the kernels in *interpret* mode
(correctness oracle — the measured kernel wall time is NOT indicative);
the bytes-moved column is backend-intrinsic and is the before/after
metric tracked across PRs. On a TPU backend the same calls compile to
Mosaic and the latency column becomes meaningful.

Run:  PYTHONPATH=src:. python benchmarks/table_bench.py \
          --layers 4 --lanes 4 --tokens 64 --d-model 128 --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.common import print_table, write_result
from repro.core import taylor


def _bytes(feat, m1, ds):
    """Analytic bytes moved by one predict + one masked update."""
    import math
    n = math.prod(feat)
    table = m1 * n * ds
    pred_out = n * ds
    return {
        # predict: astype(f32) copy (r/w) + einsum read + f32 out + cast
        "jnp_predict": table + table * 4 // ds * 2 + n * 4 + pred_out,
        # kernel: read the table once, write the prediction
        "kernel_predict": table + pred_out,
        # update: read old, write rows-stack, read stack+old for where,
        # write result (feats traffic is ~table/m1, folded in)
        "jnp_update": 3 * table + 2 * table + n * ds,
        # kernel: read old + feats once, write new once
        "kernel_update": 2 * table + n * ds,
    }


def _time(fn, *args, steps: int) -> float:
    jax.block_until_ready(fn(*args))   # compile + warm outside the window
    t0 = time.time()
    for _ in range(steps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / steps


def run(layers=4, lanes=4, tokens=64, d_model=128, order=2, steps=20,
        dtype="float32"):
    dt = jnp.dtype(dtype)
    feat = taylor.feature_shape_for(layers, lanes, tokens, d_model)
    m1 = order + 1
    key = jax.random.PRNGKey(0)
    state = taylor.init_state(order, feat, dt, lanes=lanes)
    for i, s in enumerate(range(0, 4 * m1, 4)):
        f = jax.random.normal(jax.random.fold_in(key, i), feat, jnp.float32)
        state = taylor.update_lanes(state, f.astype(dt), s,
                                    jnp.ones((lanes,), bool),
                                    backend="jnp")
    feats = jax.random.normal(jax.random.fold_in(key, 99), feat,
                              jnp.float32).astype(dt)
    mask = jnp.asarray([i % 2 == 0 for i in range(lanes)])
    step = int(state["anchor_step"][0]) + 2
    ana = _bytes(feat, m1, dt.itemsize)

    rows = []
    for backend in ("jnp", "kernel"):
        predict = jax.jit(lambda st, b=backend: taylor.predict_lanes(
            st, step, backend=b))
        update = jax.jit(lambda st, f, m, b=backend: taylor.update_lanes(
            st, f, step, m, backend=b)["diffs"])
        t_pred = _time(predict, state, steps=steps)
        t_upd = _time(update, state, feats, mask, steps=steps)
        rows.append({
            "backend": backend,
            "table_mb": round(m1 * feats.size * dt.itemsize / 2**20, 2),
            "predict_ms": round(t_pred * 1e3, 3),
            "update_ms": round(t_upd * 1e3, 3),
            "draft_step_ms": round((t_pred + t_upd) * 1e3, 3),
            "predict_bytes_mb": round(ana[f"{backend}_predict"] / 2**20, 2),
            "update_bytes_mb": round(ana[f"{backend}_update"] / 2**20, 2),
        })
    jb = ana["jnp_predict"] + ana["jnp_update"]
    kb = ana["kernel_predict"] + ana["kernel_update"]
    for r in rows:
        r["bytes_ratio_vs_jnp"] = round(
            jb / kb if r["backend"] == "kernel" else 1.0, 2)
    print_table(
        f"table backend ({layers}L x {lanes} lanes x {tokens} tok x "
        f"{d_model}d, {dtype}, m={order})", rows)
    print(f"\nfused kernels move {jb / kb:.2f}x fewer table bytes per "
          f"draft step ({jb / 2**20:.1f} MiB -> {kb / 2**20:.1f} MiB)")
    if jax.default_backend() != "tpu":
        print("NOTE: non-TPU backend -> Pallas runs in interpret mode; "
              "latency columns are oracle-mode numbers, bytes columns are "
              "backend-intrinsic.")
    path = write_result("table_bench", rows)
    print(f"wrote {path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--order", type=int, default=2)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    args = ap.parse_args()
    run(layers=args.layers, lanes=args.lanes, tokens=args.tokens,
        d_model=args.d_model, order=args.order, steps=args.steps,
        dtype=args.dtype)


if __name__ == "__main__":
    main()
