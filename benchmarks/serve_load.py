"""Sustained multi-tenant load: hundreds of mixed-policy requests
through the ``submit()``/``poll()`` lifecycle.

Where ``serve_throughput.py`` times one-shot ``serve_batched`` calls,
this harness drives the LIFECYCLE engine the way a deployment would:
bursty Poisson arrivals (seeded, fully deterministic) of heterogeneous
requests — guided and unguided diffusion, LLM decode lanes, mixed τ0,
mixed draft depths, short and full schedules, deadlines — submitted as
they "arrive", advanced one scheduler tick per loop step, completions
polled and ``release()``d as they land, and ``QueueFull`` backpressure
absorbed by retrying shed arrivals on later ticks.

Per ``--scheduler`` entry (e.g. ``fifo,wfq``) the SAME traffic trace
replays against a fresh engine and one summary row reports:

  * ``p50_latency`` / ``p99_latency`` — completion latency in loop
    ticks (finish tick − arrival tick; shed retries count against
    latency, as they would for a real client);
  * ``deadline_hit_rate`` — over the requests that carry deadlines;
  * ``share_<tenant>`` — each tenant's fraction of the service
    (schedule steps × lane streams) completed in the FIRST HALF of the
    run's completions: under saturation a weighted-fair scheduler
    front-loads high-weight tenants (``gold`` weight 4 vs ``bronze``
    weight 1), while FIFO tracks the arrival mix;
  * ``lat_<tenant>`` — per-tenant mean completion latency (the other
    face of the same fairness: WFQ trades bronze latency for gold);
  * ``qdepth_max`` and a queue-depth-over-time series
    (``serve_load_queue.json``: one row per SCHEDULER TICK per
    scheduler, read from the engine's observability registry —
    ``repro.obs`` — which samples inside ``tick()`` before admission,
    so burst peaks are captured instead of the drained post-tick
    queue) that feeds ``tools/plot_perf_trajectory.py``.

Run (repo root on the path for ``benchmarks.common``):
  PYTHONPATH=src:. python benchmarks/serve_load.py \
      --requests 60 --lanes 4 --steps 10 --scheduler fifo,wfq
  PYTHONPATH=src:. python benchmarks/serve_load.py \
      --requests 200 --lanes 8 --steps 12 --decode-frac 0.25
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (get_lm_model, get_model, print_table,
                               write_result)
from repro.configs import SpeCaConfig
from repro.serving import (DecodeWorkload, QueueFull, Request,
                           RequestPolicy, SpeCaEngine)

# tenant -> WFQ weight: gold is promised 4× the service of either
# best-effort class while backlogged
TENANTS = (("gold", 4.0), ("silver", 1.0), ("bronze", 1.0))

ROW_COLS = ("scheduler", "requests", "lanes", "ticks", "wall_s",
            "req_per_s", "p50_latency", "p99_latency",
            "deadline_hit_rate", "qdepth_max", "shed_retries",
            "completed", "dropped") + tuple(
                f"share_{t}" for t, _ in TENANTS) + tuple(
                f"lat_{t}" for t, _ in TENANTS)


def _row(**kw):
    row = {c: None for c in ROW_COLS}
    unknown = set(kw) - set(ROW_COLS)
    if unknown:
        raise KeyError(f"unknown row columns: {sorted(unknown)}")
    row.update(kw)
    return row


def build_trace(cfg, lm_cfg, args):
    """The deterministic traffic trace: ``[(arrival_tick, Request,
    deadline_slack | None), ...]`` sorted by arrival.

    Arrivals are a Poisson process (mean ``--arrival-rate`` per tick)
    whose rate quadruples during periodic bursts — the pattern that
    actually stresses admission: long queues during the burst, drain
    between. Policies are drawn per request from the mixed pool
    (tenant, guidance, τ0, schedule length, draft depth, deadline,
    workload) with the seeded generator, so every scheduler serves the
    IDENTICAL trace."""
    rng = np.random.default_rng(args.seed)
    trace = []
    t = 0
    i = 0
    while i < args.requests:
        burst = (t // 16) % 4 == 3          # every 4th 16-tick window
        lam = args.arrival_rate * (4.0 if burst else 1.0)
        n = int(rng.poisson(lam))
        for _ in range(min(n, args.requests - i)):
            tenant, weight = TENANTS[int(rng.integers(len(TENANTS)))]
            tau0 = float(rng.choice([0.2, 0.4, 0.8]))
            max_steps = int(max(args.steps // 4, 1)) \
                if rng.random() < 0.3 else None
            depth = int(rng.integers(1, args.max_draft_depth + 1))
            # feasible-when-prioritised deadline on ~30% of requests;
            # slack is resolved into an absolute tick at submit time
            slack = float(args.steps * (2 + 2 * rng.random())) \
                if rng.random() < 0.3 else None
            decode = lm_cfg is not None and rng.random() < args.decode_frac
            if decode:
                prompt = rng.integers(0, lm_cfg.vocab_size,
                                      size=(1, args.prompt_len),
                                      dtype=np.int32)
                req = Request(
                    request_id=i, cond={"tokens": prompt}, seed=i,
                    policy=RequestPolicy(
                        workload="decode", tau0=args.decode_tau0,
                        max_steps=max_steps, draft_depth=depth,
                        tenant=tenant, weight=weight))
            else:
                gs = 4.0 if rng.random() < 0.3 else None
                req = Request(
                    request_id=i,
                    cond={"labels": jnp.asarray([i % cfg.num_classes])},
                    seed=i,
                    policy=RequestPolicy(
                        guidance_scale=gs, tau0=tau0,
                        max_steps=max_steps, draft_depth=depth,
                        tenant=tenant, weight=weight))
            trace.append((t, req, slack))
            i += 1
        t += 1
    return trace


def drive(engine: SpeCaEngine, trace, *, max_ticks: int):
    """Replay one trace against one engine: submit due arrivals, tick,
    consume+release completions. Returns (records, queue-depth series,
    shed-retry count, loop ticks, wall seconds).

    The queue-depth series comes from the engine's observability
    registry (``speca_queue_depth``/``speca_in_flight``), sampled
    INSIDE ``tick()`` before admission — every scheduler tick lands one
    point. The old poll-boundary sampling read the queue only after the
    tick had already admitted the burst into free lanes, so burst peaks
    were systematically under-reported."""
    backlog = list(trace)          # (arrival_tick, req, slack), sorted
    latency = {}                   # ticket_id -> (arrival_t, tenant)
    records = []                   # (Result, latency_ticks, tenant)
    shed = 0
    t0 = time.time()
    t = 0
    while backlog or engine.pending() or engine.in_flight():
        if t >= max_ticks:
            raise RuntimeError(
                f"load run did not drain within {max_ticks} loop ticks "
                f"({len(backlog)} backlogged, {engine.pending()} queued, "
                f"{engine.in_flight()} in flight)")
        while backlog and backlog[0][0] <= t:
            arrival, req, slack = backlog[0]
            pol = req.policy
            if slack is not None:
                # resolve the trace's relative slack into an absolute
                # scheduler-tick deadline at submit time
                steps = pol.steps(
                    engine.workloads[pol.workload].num_steps)
                pol = dataclasses.replace(
                    pol, deadline=float(engine.current_tick + steps
                                        + slack))
            try:
                ticket = engine.submit(req, policy=pol)
            except QueueFull:
                shed += 1
                backlog[0] = (t + 1, req, slack)   # retry next tick
                break
            latency[ticket.ticket_id] = arrival
            backlog.pop(0)
        for res in engine.tick():
            arrival = latency.pop(res.ticket_id)
            records.append((res, t + 1 - arrival, res.tenant))
            engine.release(res.ticket_id)
        t += 1
    wall = time.time() - t0
    # per-scheduler-tick queue state from the metrics registry (one
    # point per tick, pre-admission — the burst-peak fix)
    qd = engine.obs.metrics.series("speca_queue_depth").points()
    fl = engine.obs.metrics.series("speca_in_flight").points()
    depth_series = [(int(x), int(q), int(f))
                    for (x, q), (_, f) in zip(qd, fl)]
    dropped = engine.shutdown()
    for res in dropped:            # should be empty: the loop drains
        arrival = latency.pop(res.ticket_id)
        records.append((res, t - arrival, res.tenant))
    return records, depth_series, shed, t, wall


def summarize(name: str, records, depth_series, shed, ticks, wall,
              lanes: int):
    lats = np.asarray([lat for r, lat, _ in records if r.completed],
                      np.float64)
    met = [r.deadline_met for r, _, _ in records
           if r.deadline is not None]
    hit = sum(bool(m) for m in met) / len(met) if met else None
    completed = [rec for rec in records if rec[0].completed]
    # fairness: who got served EARLY — each tenant's share of the
    # service completed in the first half of the run's completions
    half = completed[:max(len(completed) // 2, 1)]
    service = {t: 0.0 for t, _ in TENANTS}
    for res, _, tenant in half:
        # service in schedule-step decisions (a guided pair is one
        # decision per step, same as Result accounting)
        service[tenant] += res.num_full + res.num_spec
    total = sum(service.values()) or 1.0
    by_tenant = {t: [lat for _, lat, tn in completed if tn == t]
                 for t, _ in TENANTS}
    return _row(
        scheduler=name,
        requests=len(records), lanes=lanes, ticks=ticks,
        wall_s=round(wall, 2),
        req_per_s=round(len(records) / max(wall, 1e-9), 3),
        p50_latency=round(float(np.percentile(lats, 50)), 1),
        p99_latency=round(float(np.percentile(lats, 99)), 1),
        deadline_hit_rate=None if hit is None else round(hit, 3),
        qdepth_max=max(q + f for _, q, f in depth_series),
        shed_retries=shed,
        completed=len(completed),
        dropped=len(records) - len(completed),
        **{f"share_{t}": round(service[t] / total, 3)
           for t, _ in TENANTS},
        **{f"lat_{t}": round(float(np.mean(by_tenant[t])), 1)
           if by_tenant[t] else None for t, _ in TENANTS})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dit", choices=["dit", "flux"])
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=12,
                    help="diffusion schedule length")
    ap.add_argument("--scheduler", default="fifo,wfq",
                    help="comma list of admission schedulers; the same "
                         "trace replays against each")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="mean Poisson arrivals per tick (4x in bursts)")
    ap.add_argument("--max-queue", type=int, default=32,
                    help="admission-queue bound (QueueFull backpressure)")
    ap.add_argument("--max-draft-depth", type=int, default=2)
    ap.add_argument("--decode-frac", type=float, default=0.25,
                    help="fraction of traffic routed to LLM decode "
                         "lanes (0 disables the decode workload)")
    ap.add_argument("--lm-arch", default="mamba2-130m")
    ap.add_argument("--decode-tau0", type=float, default=5.0)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-ticks", type=int, default=100_000,
                    help="liveness bound on the drive loop")
    args = ap.parse_args()

    cfg, dcfg, params = get_model(args.model)
    dcfg = dataclasses.replace(dcfg, num_inference_steps=args.steps)
    scfg = SpeCaConfig(taylor_order=2, max_draft=8, tau0=0.4, beta=0.9)
    lm = get_lm_model(args.lm_arch) if args.decode_frac > 0 else None
    lm_cfg = lm[0] if lm else None

    trace = build_trace(cfg, lm_cfg, args)
    n_decode = sum(r.policy.workload == "decode" for _, r, _ in trace)
    print(f"trace: {len(trace)} requests over "
          f"{trace[-1][0] + 1} arrival ticks "
          f"({n_decode} decode, {len(trace) - n_decode} diffusion)")

    def make_engine(sched: str) -> SpeCaEngine:
        workloads = {}
        if lm is not None:
            workloads["decode"] = DecodeWorkload(
                lm[0], lm[1], SpeCaConfig(tau0=args.decode_tau0),
                max_new_tokens=args.gen_len,
                max_seq_len=args.prompt_len + args.gen_len)
        eng = SpeCaEngine(cfg, params, dcfg, scfg, scheduler=sched,
                          max_queue=args.max_queue,
                          max_draft_depth=args.max_draft_depth,
                          lanes=args.lanes, workloads=workloads,
                          obs=True)
        # compile outside the timed drive loop: the lifecycle diffusion
        # session runs the mixed slot program, decode the plain one
        eng.warmup({"labels": jnp.asarray([0])}, lanes=args.lanes,
                   mixed=True)
        if lm is not None:
            warm = np.zeros((1, args.prompt_len), np.int32)
            eng.warmup({"tokens": warm}, lanes=args.lanes,
                       workload="decode")
        return eng

    rows, depth_rows = [], []
    for sched in [s.strip() for s in args.scheduler.split(",") if s]:
        eng = make_engine(sched)
        records, depth_series, shed, ticks, wall = drive(
            eng, trace, max_ticks=args.max_ticks)
        rows.append(summarize(sched, records, depth_series, shed,
                              ticks, wall, args.lanes))
        depth_rows += [{"scheduler": sched, "tick": t, "queued": q,
                        "in_flight": f} for t, q, f in depth_series]
        r = rows[-1]
        print(f"{sched}: p50 {r['p50_latency']} / p99 "
              f"{r['p99_latency']} ticks, hit-rate "
              f"{r['deadline_hit_rate']}, max queue depth "
              f"{r['qdepth_max']}, gold/bronze early share "
              f"{r['share_gold']}/{r['share_bronze']}")

    print_table(f"serve_load ({args.model}, {args.requests} requests, "
                f"lanes={args.lanes})", rows)
    path = write_result("serve_load", rows)
    qpath = write_result("serve_load_queue", depth_rows)
    print(f"wrote {path} and {qpath}")


if __name__ == "__main__":
    main()
