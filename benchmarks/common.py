"""Shared benchmark infrastructure: model zoo, quality proxies, accounting.

Scale adaptation (DESIGN.md §8): the paper's models are 0.7B–13B and its
metrics need external scorers (ImageReward, VBench, Inception). At CPU
scale we train reduced models of the same families on synthetic
class-structured latents and report *declared proxies*:

  * ``rel_dev``     — relative L2 between the accelerated sample and the
                      full-computation sample from the same seed (trajectory
                      faithfulness; primary).
  * ``fid_proxy``   — Fréchet distance between Gaussian fits (ridge-
                      regularised) of generated vs reference latent sets.
  * ``cond_score``  — cosine alignment between each generated latent and
                      its class template (ImageReward/CLIP-proxy: did the
                      conditioning survive acceleration?).
  * ``temporal``    — mean frame-to-frame correlation error vs the full
                      sampler's value (VBench-proxy component, video only).

Relative orderings across methods — not absolute values — are the claims
being reproduced.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import (DiffusionConfig, SpeCaConfig, TrainConfig,
                           get_config, reduced)
from repro.core import complexity as CX
from repro.core.baselines import (CachePolicy, ab2, cached_sample, fora,
                                  step_reduction_sample, taylorseer, teacache)
from repro.core.speca import speca_sample
from repro.data import synthetic as syn
from repro.diffusion.pipeline import sample_full
from repro.layers import model as M
from repro.training.diffusion_trainer import train_diffusion

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")
MODELS = os.path.join(ART, "models")
RESULTS = os.path.join(ART, "results")


# ---------------------------------------------------------------------------
# Model zoo (train once, cache on disk)
# ---------------------------------------------------------------------------

def zoo_config(name: str):
    import dataclasses as dc
    if name == "dit":
        cfg = dc.replace(reduced(get_config("dit-xl2")), num_layers=4,
                         d_model=128, d_ff=512, num_heads=4, num_kv_heads=4,
                         num_classes=8)
        dcfg = DiffusionConfig(num_inference_steps=50, latent_size=16,
                               schedule="cosine")
        tcfg = TrainConfig(global_batch=16, steps=300, lr=2e-3)
    elif name == "flux":
        cfg = dc.replace(reduced(get_config("flux-like")), num_layers=4,
                         d_model=128, d_ff=512, num_heads=4, num_kv_heads=4,
                         in_channels=4, cond_dim=32, num_classes=8)
        dcfg = DiffusionConfig(num_inference_steps=50, latent_size=16,
                               schedule="rectified_flow")
        tcfg = TrainConfig(global_batch=16, steps=300, lr=2e-3)
    elif name == "video":
        cfg = dc.replace(reduced(get_config("hunyuan-video-like")),
                         num_layers=3, d_model=96, d_ff=384, num_heads=4,
                         num_kv_heads=4, in_channels=4, cond_dim=32,
                         num_classes=8)
        dcfg = DiffusionConfig(num_inference_steps=50, latent_size=8,
                               schedule="rectified_flow", num_frames=4)
        tcfg = TrainConfig(global_batch=8, steps=250, lr=2e-3)
    else:
        raise KeyError(name)
    return cfg, dcfg, tcfg


def _video_batch(cfg, dcfg, indices):
    """Class-conditional video latents: spatial pattern drifting per frame."""
    data_cfg = syn.GMLatentConfig(num_classes=max(cfg.num_classes, 1),
                                  latent_size=dcfg.latent_size,
                                  channels=cfg.in_channels)
    base = syn.gm_latent_batch(data_cfg, indices)
    lat = base["latents"]                       # [B, H, W, C]
    frames = []
    for f in range(dcfg.num_frames):
        frames.append(jnp.roll(lat, shift=f, axis=2) * (1.0 - 0.05 * f))
    return {"latents": jnp.stack(frames, axis=1), "labels": base["labels"]}


def get_model(name: str, *, verbose: bool = True):
    """Returns (cfg, dcfg, params), training + caching on first use."""
    cfg, dcfg, tcfg = zoo_config(name)
    path = os.path.join(MODELS, name)
    key = jax.random.PRNGKey(0)
    template = jax.eval_shape(lambda: M.init_params(cfg, key))
    if os.path.isdir(path):
        params = restore_checkpoint(
            path, jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                               template))
        return cfg, dcfg, params
    if verbose:
        print(f"[zoo] training {name} ({tcfg.steps} steps)...")
    if name == "video":
        params = _train_video(cfg, dcfg, tcfg)
    else:
        out = train_diffusion(cfg, dcfg, tcfg, verbose=verbose)
        params = out["state"]["params"]
    save_checkpoint(path, params, step=tcfg.steps)
    return cfg, dcfg, params


def _train_video(cfg, dcfg, tcfg):
    from repro.optim.adamw import (AdamWConfig, cosine_warmup_schedule,
                                   init_opt_state)
    from repro.training.diffusion_trainer import diffusion_train_step
    key = jax.random.PRNGKey(tcfg.seed)
    params = M.init_params(cfg, jax.random.fold_in(key, 1))
    opt = AdamWConfig(lr=tcfg.lr)
    state = {"params": params, "opt": init_opt_state(params),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = jax.jit(partial(diffusion_train_step, cfg, dcfg, opt))
    sched = cosine_warmup_schedule(tcfg.warmup, tcfg.steps)
    for step in range(tcfg.steps):
        idx = jnp.arange(step * tcfg.global_batch,
                         (step + 1) * tcfg.global_batch)
        batch = _video_batch(cfg, dcfg, idx)
        if cfg.cond_dim:
            batch["cond"] = syn.cond_stub_batch(
                tcfg.global_batch, 8, cfg.cond_dim, idx)
        state, _ = step_fn(state, batch, jax.random.fold_in(key, step),
                           sched(step))
    return state["params"]


def get_lm_model(arch: str = "mamba2-130m", *, steps: int = 30,
                 verbose: bool = True):
    """Reduced LM for decode-workload serving benchmarks: returns
    ``(cfg, params)``, training briefly on the synthetic LM stream and
    caching on disk like the diffusion zoo (a trained net gives stable
    feature trajectories, so decode accept rates are reproducible
    across CI runs instead of artifacts of random init)."""
    from repro.optim.adamw import AdamWConfig
    from repro.training import lm as T
    cfg = reduced(get_config(arch))
    path = os.path.join(MODELS, f"lm-{arch}")
    key = jax.random.PRNGKey(0)
    if os.path.isdir(path):
        template = jax.eval_shape(lambda: M.init_params(cfg, key))
        params = restore_checkpoint(
            path, jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                               template))
        return cfg, params
    if verbose:
        print(f"[zoo] training lm-{arch} ({steps} steps)...")
    state = T.make_train_state(cfg, key, AdamWConfig(lr=1e-3))
    data_cfg = syn.LMStreamConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  num_codebooks=cfg.num_codebooks)
    it = syn.ShardedIterator(partial(syn.lm_batch, data_cfg), 8)
    step_fn = jax.jit(partial(T.train_step, cfg,
                              AdamWConfig(lr=1e-3)))
    for _ in range(steps):
        state, _ = step_fn(state, next(it))
    params = state["params"]
    save_checkpoint(path, params, step=steps)
    return cfg, params


def make_cond(cfg, dcfg, batch: int, seed: int = 123) -> Dict[str, Any]:
    cond: Dict[str, Any] = {}
    key = jax.random.PRNGKey(seed)
    if cfg.num_classes:
        cond["labels"] = jax.random.randint(key, (batch,), 0,
                                            cfg.num_classes)
    if cfg.cond_dim:
        cond["cond"] = syn.cond_stub_batch(
            batch, 8, cfg.cond_dim, jnp.arange(seed, seed + batch))
    return cond


# ---------------------------------------------------------------------------
# Quality proxies
# ---------------------------------------------------------------------------

def rel_dev(x, x_ref) -> float:
    return float(jnp.linalg.norm(x - x_ref) / jnp.linalg.norm(x_ref))


def _gauss_fit(x: np.ndarray, ridge: float = 1e-3):
    mu = x.mean(0)
    xc = x - mu
    cov = xc.T @ xc / max(len(x) - 1, 1) + ridge * np.eye(x.shape[1])
    return mu, cov


def frechet(gen, ref, ridge: float = 1e-3) -> float:
    """FID-proxy: Fréchet distance between Gaussian fits (no scipy —
    matrix square roots via eigendecomposition)."""
    g = np.asarray(gen, np.float64).reshape(len(gen), -1)
    r = np.asarray(ref, np.float64).reshape(len(ref), -1)
    mu_g, cov_g = _gauss_fit(g, ridge)
    mu_r, cov_r = _gauss_fit(r, ridge)
    diff = float(((mu_g - mu_r) ** 2).sum())
    # tr(Cg + Cr − 2·(Cg^{1/2} Cr Cg^{1/2})^{1/2}) via eigendecomposition
    w, v = np.linalg.eigh(cov_g)
    w = np.clip(w, 0, None)
    sq = (v * np.sqrt(w)) @ v.T
    inner = sq @ cov_r @ sq
    wi = np.clip(np.linalg.eigvalsh(inner), 0, None)
    tr = float(np.trace(cov_g) + np.trace(cov_r) - 2 * np.sqrt(wi).sum())
    return diff + max(tr, 0.0)


def class_templates(cfg, dcfg) -> np.ndarray:
    data_cfg = syn.GMLatentConfig(num_classes=max(cfg.num_classes, 1),
                                  latent_size=dcfg.latent_size,
                                  channels=cfg.in_channels, noise_scale=0.0)
    out = []
    for c in range(data_cfg.num_classes):
        out.append(np.asarray(syn._class_pattern(data_cfg,
                                                 jnp.asarray(c))))
    return np.stack(out)


def cond_score(gen: np.ndarray, labels: np.ndarray, templates: np.ndarray
               ) -> float:
    """Mean cosine(generated latent, class template) — CLIP/reward proxy."""
    sims = []
    for x, lab in zip(gen, labels):
        if x.ndim == 4:     # video: average frames
            x = x.mean(0)
        t = templates[int(lab)].reshape(-1)
        xf = np.asarray(x, np.float64).reshape(-1)
        sims.append(float(xf @ t / (np.linalg.norm(xf) * np.linalg.norm(t)
                                    + 1e-9)))
    return float(np.mean(sims))


def temporal_consistency(gen: np.ndarray) -> float:
    """Mean adjacent-frame correlation (video). gen [B,F,H,W,C]."""
    sims = []
    for x in gen:
        for f in range(x.shape[0] - 1):
            a = x[f].reshape(-1)
            b = x[f + 1].reshape(-1)
            sims.append(float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)
                                       + 1e-9)))
    return float(np.mean(sims))


def reference_latents(cfg, dcfg, n: int = 64) -> np.ndarray:
    data_cfg = syn.GMLatentConfig(num_classes=max(cfg.num_classes, 1),
                                  latent_size=dcfg.latent_size,
                                  channels=cfg.in_channels)
    batch = syn.gm_latent_batch(data_cfg, jnp.arange(50_000, 50_000 + n))
    return np.asarray(batch["latents"])


# ---------------------------------------------------------------------------
# Method runner + accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MethodResult:
    name: str
    samples: np.ndarray
    num_full: int
    num_spec: int
    steps: int
    flops: float
    speedup: float
    wall_s: float
    alpha: float
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)


def run_method(name: str, cfg, dcfg, params, cond, batch: int, key,
               **kw) -> MethodResult:
    """name: full | steps_<frac> | fora_<N> | taylorseer_<N>_<O> |
    teacache_<l> | ab2_<N> | speca_<tau0>[_<draft>]"""
    n_tok = (dcfg.latent_size // cfg.patch_size) ** 2 \
        * max(dcfg.num_frames, 1)
    full_flops = CX.forward_flops(cfg, n_tok) * batch
    ver_flops = CX.verify_flops(cfg, n_tok) * batch
    S = dcfg.num_inference_steps
    t0 = time.time()

    if name == "full":
        x, _ = jax.jit(lambda k: sample_full(cfg, params, dcfg, k, cond,
                                             batch))(key)
        x = jax.block_until_ready(x)
        fl = S * full_flops
        return MethodResult(name, np.asarray(x), S, 0, S, fl, 1.0,
                            time.time() - t0, 0.0)

    parts = name.split("_")
    kind = parts[0]
    if kind == "steps":
        frac = float(parts[1])
        x, st = step_reduction_sample(cfg, params, dcfg, frac, key, cond,
                                      batch)
        x = jax.block_until_ready(x)
        fl = st["num_steps"] * full_flops
        return MethodResult(name, np.asarray(x), st["num_steps"], 0,
                            st["num_steps"], fl, S * full_flops / fl,
                            time.time() - t0, 0.0)

    if kind == "speca":
        tau0 = float(parts[1])
        draft = parts[2] if len(parts) > 2 else "taylor"
        scfg = kw.pop("scfg", None) or SpeCaConfig(
            taylor_order=2, max_draft=8, tau0=tau0, beta=0.9, **kw)
        x, st = jax.jit(lambda k: speca_sample(
            cfg, params, dcfg, scfg, k, cond, batch,
            draft_mode=draft))(key)
        x = jax.block_until_ready(x)
        nf, nsp = int(st["num_full"]), int(st["num_spec"])
        fl = nf * full_flops + int(st["num_attempted"]) * ver_flops
        return MethodResult(name, np.asarray(x), nf, nsp, S, fl,
                            S * full_flops / fl, time.time() - t0,
                            float(st["alpha"]),
                            extra={"attempted": int(st["num_attempted"])})

    if kind == "fora":
        policy = fora(int(parts[1]))
    elif kind == "taylorseer":
        policy = taylorseer(int(parts[1]),
                            int(parts[2]) if len(parts) > 2 else 2)
    elif kind == "ab2":
        policy = ab2(int(parts[1]))
    elif kind == "teacache":
        policy = teacache(float(parts[1]))
    else:
        raise KeyError(name)
    x, st = jax.jit(lambda k: cached_sample(cfg, params, dcfg, policy, k,
                                            cond, batch))(key)
    x = jax.block_until_ready(x)
    nf = int(st["num_full"])
    # non-verifying policies pay only the draft glue on predicted steps
    glue = CX.glue_flops(cfg, n_tok) * batch
    fl = nf * full_flops + (S - nf) * glue
    return MethodResult(name, np.asarray(x), nf, S - nf, S, fl,
                        S * full_flops / fl, time.time() - t0,
                        float(st["alpha"]))


def evaluate(res: MethodResult, x_full: np.ndarray, cfg, dcfg, cond,
             templates, ref: Optional[np.ndarray]) -> Dict[str, float]:
    out = {
        "method": res.name,
        "steps_full": res.num_full,
        "steps_spec": res.num_spec,
        "alpha": round(res.alpha, 4),
        "tflops": round(res.flops / 1e12, 6),
        "speedup_flops": round(res.speedup, 3),
        "wall_s": round(res.wall_s, 2),
        "rel_dev": round(rel_dev(jnp.asarray(res.samples),
                                 jnp.asarray(x_full)), 5),
    }
    if cfg.num_classes and "labels" in cond:
        out["cond_score"] = round(
            cond_score(res.samples, np.asarray(cond["labels"]), templates), 5)
    if ref is not None and res.samples.ndim == 4:
        out["fid_proxy"] = round(frechet(res.samples, ref), 4)
    if res.samples.ndim == 5:
        out["temporal"] = round(temporal_consistency(res.samples), 5)
    return out


def write_result(table: str, rows: List[Dict[str, Any]]) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{table}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def print_table(title: str, rows: List[Dict[str, Any]]) -> None:
    if not rows:
        return
    cols = list(rows[0].keys())
    print(f"\n== {title} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
