"""Table 3 analogue: class-conditional generation on the reduced DiT.

Methods: DDIM step reduction, FORA, TaylorSeer, AB2, TeaCache, SpeCa at
three aggressiveness levels. Reported: FLOPs speedup, trajectory deviation,
FID-proxy, conditioning score. Claim under test: SpeCa holds quality at
accelerations where unverified caching degrades (paper: FID 2.72 @5× vs
FORA 9.24, ToCa 12.86; catastrophic at 6.8×+).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C

METHODS = [
    "full",
    "steps_0.5", "steps_0.2", "steps_0.14",
    "fora_4", "fora_7",
    "taylorseer_4_2", "taylorseer_7_2",
    "ab2_5",
    "teacache_1.8", "teacache_3.5",
    "speca_0.1", "speca_0.3", "speca_0.6",
]


def run(batch: int = 16, methods=None, seed: int = 7):
    cfg, dcfg, params = C.get_model("dit")
    cond = C.make_cond(cfg, dcfg, batch)
    key = jax.random.PRNGKey(seed)
    templates = C.class_templates(cfg, dcfg)
    ref = C.reference_latents(cfg, dcfg, n=64)

    rows = []
    x_full = None
    for name in (methods or METHODS):
        res = C.run_method(name, cfg, dcfg, params, cond, batch, key)
        if name == "full":
            x_full = res.samples
        rows.append(C.evaluate(res, x_full, cfg, dcfg, cond, templates, ref))
    C.print_table("table3_dit (class-conditional, DDIM-50 base)", rows)
    C.write_result("table3_dit", rows)
    return rows


if __name__ == "__main__":
    run()
